//! The serving engine: prefill, index construction, and the Algorithm-1
//! decode step.
//!
//! One [`Engine`] per model replica; one [`Session`] per request. The
//! decode step is the paper's Algorithm 1 verbatim:
//!
//! 1. device partial attention over the static set `W` via the AOT
//!    `static_attn` artifact (Pallas flash_decode inside);
//! 2. host partial attention over the retrieved set `Ω` (per-query-head
//!    retrieval fanned out across threads, Appendix C) plus the small
//!    overflow buffer of not-yet-indexed tokens;
//! 3. exact γ-combine of the partials (Eq. 4/5);
//! 4. FFN/projections via the per-op artifacts, greedy sampling;
//! 5. online index maintenance: completed background work is applied,
//!    then overflow buffers past the configured watermark are snapshotted
//!    and handed to the per-session maintenance worker (recent decode
//!    queries ride along as RoarGraph's attention-aware wiring context).
//!    The worker grows the segmented group store (O(batch), the prefix is
//!    never recopied) and publishes each head's index with a
//!    double-buffered generation-counted swap — decode keeps reading the
//!    front the whole time, and cost stays bounded for arbitrarily long
//!    generations. The same queue tombstones evicted tokens when the
//!    `retrieval.eviction` window retirement is enabled.
//!
//! Prefill streams the prompt through the B=256 artifacts, computes exact
//! causal attention on the host (the "GPU prefill" of §3.3 — full
//! attention is required anyway to produce the next layer's input), and
//! captures per-head query histories, which become RoarGraph's training
//! set.

use crate::attention::{attend_group_mq, attend_subset, combine_into, PartialAttention};
use crate::baselines::{
    build_retriever_for_policy, GroupShared, HostRetriever, RetrieverInputs, StreamingRetriever,
};
use crate::config::{Method, ServeConfig};
use crate::policy::{Calibrator, HeadPolicy, PolicyMap, PolicyMode};
use crate::index::KeyStore;
use crate::kernel;
use crate::kvcache::{StaticPattern, TieredKvCache};
use crate::metrics::PhaseBreakdown;
use crate::telemetry::{self, Phase, SpanAcc, Stopwatch};
use crate::model::maintain::{
    run_compact, run_drain, run_evict, CompactJob, Done, DoneKind, DrainJob, EvictJob, Job,
    MaintenanceState,
};
use crate::model::weights::Weights;
use crate::runtime::{literal_to_f32, Runtime};
use crate::tensor::Matrix;
use crate::util::contain::contained;
use crate::util::parallel;
use anyhow::{Context, Result};
use std::sync::Arc;

/// Chunk width of the prefill artifacts (matches aot.py `batches`).
pub const PREFILL_CHUNK: usize = 256;

/// A model replica: runtime + weights + method configuration.
pub struct Engine {
    pub rt: Runtime,
    pub weights: Weights,
    pub cfg: ServeConfig,
    /// Device-resident weights (uploaded once, reused every call).
    lits: WeightBuffers,
}

struct LayerBuffers {
    g: xla::PjRtBuffer,
    wq: xla::PjRtBuffer,
    wk: xla::PjRtBuffer,
    wv: xla::PjRtBuffer,
    wo: xla::PjRtBuffer,
    g2: xla::PjRtBuffer,
    w1: xla::PjRtBuffer,
    w3: xla::PjRtBuffer,
    w2: xla::PjRtBuffer,
}

/// Weights resident on the device: uploaded once at engine construction
/// and referenced by every artifact call (EXPERIMENTS.md §Perf: the
/// literal path re-transferred ~30MB of weights per decode step).
struct WeightBuffers {
    table: xla::PjRtBuffer,
    layers: Vec<LayerBuffers>,
    gf: xla::PjRtBuffer,
    wu: xla::PjRtBuffer,
}

/// Per-request decode state.
pub struct Session {
    /// The retrieval method this session's retrievers were built for (may
    /// differ from the engine's configured method via
    /// [`Engine::session_for_method`] / [`Engine::synthetic_session`]).
    pub method: Method,
    /// KV caches per (layer, kv_head): `caches[layer][kv_head]`.
    pub caches: Vec<Vec<TieredKvCache>>,
    /// Prefill query history per (layer, q_head).
    pub q_history: Vec<Vec<Matrix>>,
    /// Host retrievers per (layer, q_head), built after prefill.
    pub retrievers: Vec<Vec<Arc<dyn HostRetriever>>>,
    /// Shared per-(layer, kv_head) group state: ONE segmented dense key
    /// store and ONE dense→absolute id map per GQA group (Appendix C) —
    /// grown by the maintenance worker on overflow drains.
    pub groups: Vec<Vec<Arc<GroupShared>>>,
    /// Background maintenance: worker handle, in-flight drain set, stats.
    pub maint: MaintenanceState,
    /// Recent decode queries per (layer, q_head) (bounded ring, oldest
    /// first): the bipartite training side for attention-aware inserts.
    pub recent_q: Vec<Vec<Matrix>>,
    /// Per-query-head host-id scratch, reused across layers and tokens:
    /// the retrieved ∪ overflow id set is assembled here each step
    /// instead of cloning `retrieved[h].ids` every head × layer × token.
    host_ids: Vec<Vec<u32>>,
    /// Hidden state of the last processed token.
    pub x_last: Vec<f32>,
    /// Tokens processed so far.
    pub len: usize,
    /// Scan statistics (for Table 5 / Fig 6 accounting).
    pub scanned_total: u64,
    pub retrievals: u64,
    /// Overflow tokens drained out of the linear-scan buffer so far —
    /// folded into the ANN index, or dropped outright under StreamingLLM
    /// semantics.
    pub drained_tokens: u64,
    /// Number of drain operations performed.
    pub drains: u64,
    /// True once any removal (eviction or truncation) has tombstoned index
    /// slots — until then the reclaim trigger skips its per-group front
    /// polling entirely (sessions that never remove pay nothing).
    pub had_removals: bool,
    /// Per-(layer, q_head) retrieval-vs-streaming assignment (the policy
    /// layer). All-Retrieval when the policy is off or the method is not
    /// index-backed; mirrors which heads hold a [`StreamingRetriever`].
    pub policy: PolicyMap,
    /// In-flight calibration pass: `Some` only while profiling decode
    /// steps are still being accumulated under `PolicyMode::Calibrated`.
    pub calib: Option<Calibrator>,
    /// Host index bytes released by streaming-head specialization (the
    /// done-event metric; 0 until a calibration decides, since statically
    /// assigned heads never build an index in the first place).
    pub index_bytes_avoided: u64,
    /// Per-request span tree (phase hit counts + wall seconds), recorded
    /// by [`crate::telemetry::span_record`] only while the
    /// `serving.telemetry.spans` knob is on; stays all-zero otherwise.
    /// The coordinator resets it at admission and reads it at retirement.
    pub spans: SpanAcc,
}

/// One decode step's outputs.
pub struct DecodeOutput {
    pub token: u32,
    pub breakdown: PhaseBreakdown,
}

/// One session's slot in a fused decode wave ([`Engine::decode_wave`]):
/// the session to advance and the token to feed it.
pub struct WaveItem<'a> {
    pub sess: &'a mut Session,
    pub token: u32,
}

/// Retriever construction result: per-(layer, q_head) retrievers plus the
/// per-(layer, kv_head) shared group state they index into.
type RetrieverBuild = (Vec<Vec<Arc<dyn HostRetriever>>>, Vec<Vec<Arc<GroupShared>>>);

/// Append one query to a bounded ring (oldest rows evicted by periodic
/// compaction, amortised O(1) per push).
fn push_recent(ring: &mut Matrix, q: &[f32], cap: usize) {
    if cap == 0 {
        return;
    }
    ring.push_row(q);
    if ring.rows() > cap * 2 {
        *ring = ring.keep_last_rows(cap);
    }
}

impl Engine {
    pub fn new(rt: Runtime, weights: Weights, cfg: ServeConfig) -> Result<Engine> {
        // One-time process-wide telemetry arming (span flag, trace file,
        // flight-recorder capacity) — idempotent across replicas.
        telemetry::configure(&cfg.serving.telemetry);
        weights
            .validate(&rt.meta().spec)
            .map_err(|e| anyhow::anyhow!("weights do not match manifest: {e}"))?;
        let lits = WeightBuffers {
            table: rt.upload_matrix(&weights.table)?,
            layers: weights
                .layers
                .iter()
                .map(|l| -> Result<LayerBuffers> {
                    Ok(LayerBuffers {
                        g: rt.upload_f32(&l.g, &[l.g.len()])?,
                        wq: rt.upload_matrix(&l.wq)?,
                        wk: rt.upload_matrix(&l.wk)?,
                        wv: rt.upload_matrix(&l.wv)?,
                        wo: rt.upload_matrix(&l.wo)?,
                        g2: rt.upload_f32(&l.g2, &[l.g2.len()])?,
                        w1: rt.upload_matrix(&l.w1)?,
                        w3: rt.upload_matrix(&l.w3)?,
                        w2: rt.upload_matrix(&l.w2)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?,
            gf: rt.upload_f32(&weights.gf, &[weights.gf.len()])?,
            wu: rt.upload_matrix(&weights.wu)?,
        };
        Ok(Engine { rt, weights, cfg, lits })
    }

    /// Load an engine from a config: runtime from `artifacts_dir` (PJRT
    /// when artifacts exist, the native backend otherwise), weights by
    /// preset convention (induction construction or seeded random).
    pub fn from_config(cfg: ServeConfig) -> Result<Engine> {
        let rt = Runtime::load_auto(&cfg.artifacts_dir, &cfg.model)
            .with_context(|| format!("loading preset {}", cfg.model))?;
        let spec = rt.meta().spec.clone();
        let weights = if crate::model::induction::is_induction(&spec) {
            crate::model::induction::build(&spec)
        } else {
            Weights::random(&spec, cfg.seed)
        };
        Engine::new(rt, weights, cfg)
    }

    pub fn spec(&self) -> &crate::runtime::manifest::SpecMeta {
        &self.rt.meta().spec
    }

    fn scale(&self) -> f32 {
        1.0 / (self.spec().head_dim as f32).sqrt()
    }

    /// Run the prompt through the model (chunked prefill), build host
    /// retrievers, and return a ready-to-decode session.
    pub fn prefill(&self, tokens: &[u32]) -> Result<Session> {
        let t = Stopwatch::start();
        let spec = self.spec().clone();
        let pattern = self.cfg.pattern;
        let n = tokens.len();
        anyhow::ensure!(n > 0, "empty prompt");

        let mut caches: Vec<Vec<TieredKvCache>> = (0..spec.layers)
            .map(|_| {
                (0..spec.kv_heads).map(|_| TieredKvCache::new(spec.head_dim, pattern)).collect()
            })
            .collect();
        let mut q_history: Vec<Vec<Matrix>> = (0..spec.layers)
            .map(|_| (0..spec.q_heads).map(|_| Matrix::zeros(0, spec.head_dim)).collect())
            .collect();

        let mut x_last = vec![0.0f32; spec.d_model];
        let mut start = 0usize;
        while start < n {
            let take = (n - start).min(PREFILL_CHUNK);
            // Pad ids and positions to the chunk width.
            let mut ids = vec![0i32; PREFILL_CHUNK];
            let mut pos = vec![0.0f32; PREFILL_CHUNK * spec.d_model];
            for i in 0..take {
                ids[i] = tokens[start + i] as i32;
                let code = crate::model::position_code(&spec, start + i);
                pos[i * spec.d_model..(i + 1) * spec.d_model].copy_from_slice(&code);
            }
            let ids_b = self.rt.upload_i32(&ids, &[PREFILL_CHUNK])?;
            let pos_b = self.rt.upload_f32(&pos, &[PREFILL_CHUNK, spec.d_model])?;
            let outs = self.rt.exec_b("embed_b256", &[&self.lits.table, &ids_b, &pos_b])?;
            let mut x = Matrix::from_vec(
                PREFILL_CHUNK,
                spec.d_model,
                literal_to_f32(&outs[0])?,
            );

            for layer in 0..spec.layers {
                let ll = &self.lits.layers[layer];
                let x_b = self.rt.upload_matrix(&x)?;
                let outs =
                    self.rt.exec_b("qkv_b256", &[&x_b, &ll.g, &ll.wq, &ll.wk, &ll.wv])?;
                let q = literal_to_f32(&outs[0])?; // [B, H, dh]
                let k = literal_to_f32(&outs[1])?; // [B, KV, dh]
                let v = literal_to_f32(&outs[2])?;
                let dh = spec.head_dim;
                // Append K/V for the real tokens of this chunk.
                for i in 0..take {
                    for kvh in 0..spec.kv_heads {
                        let off = (i * spec.kv_heads + kvh) * dh;
                        caches[layer][kvh].append(&k[off..off + dh], &v[off..off + dh]);
                    }
                }
                for (h, hist) in q_history[layer].iter_mut().enumerate() {
                    for i in 0..take {
                        let off = (i * spec.q_heads + h) * dh;
                        hist.push_row(&q[off..off + dh]);
                    }
                }
                // Exact causal attention for this chunk's queries over the
                // cache so far (host side, parallel over (query, head)).
                let attn = self.prefill_attention(
                    &caches[layer],
                    &q,
                    start,
                    take,
                    spec.q_heads,
                    spec.kv_heads,
                    dh,
                )?;
                let x_b = self.rt.upload_matrix(&x)?;
                let attn_b = self.rt.upload_matrix(&attn)?;
                let outs = self.rt.exec_b(
                    "post_b256",
                    &[&x_b, &attn_b, &ll.wo, &ll.g2, &ll.w1, &ll.w3, &ll.w2],
                )?;
                x = Matrix::from_vec(PREFILL_CHUNK, spec.d_model, literal_to_f32(&outs[0])?);
            }
            if start + take == n {
                x_last.copy_from_slice(x.row(take - 1));
            }
            start += take;
        }

        for layer in caches.iter_mut() {
            for cache in layer.iter_mut() {
                cache.seal_prefill();
            }
        }

        let policy = self.initial_policy(self.cfg.method);
        let (retrievers, groups) =
            self.build_retrievers_with(&caches, &q_history, self.cfg.method, &policy)?;
        let recent_q = self.empty_recent_rings();
        let mut sess = Session {
            method: self.cfg.method,
            caches,
            q_history,
            retrievers,
            groups,
            maint: MaintenanceState::new(),
            recent_q,
            host_ids: Vec::new(),
            x_last,
            len: n,
            scanned_total: 0,
            retrievals: 0,
            drained_tokens: 0,
            drains: 0,
            had_removals: false,
            calib: self.new_calibrator(self.cfg.method),
            policy,
            index_bytes_avoided: 0,
            spans: SpanAcc::default(),
        };
        let secs = t.elapsed_s();
        telemetry::span_record(&mut sess.spans, Phase::Prefill, t.started(), secs, 0);
        telemetry::registry().histogram("engine.prefill_s").record(secs);
        Ok(sess)
    }

    /// The build-time policy for `method`: the static override map. Under
    /// `calibrated` mode heads start Retrieval (minus overrides) and flip
    /// only after the profiling window closes; non-index-backed methods
    /// are never specialized — their assignment stays the identity.
    fn initial_policy(&self, method: Method) -> PolicyMap {
        let spec = self.spec();
        if method.index_backed() {
            self.cfg.policy.static_map(spec.layers, spec.q_heads)
        } else {
            PolicyMap::all_retrieval(spec.layers, spec.q_heads)
        }
    }

    /// A fresh profiling pass when the config asks for one and the method
    /// can act on its verdict.
    fn new_calibrator(&self, method: Method) -> Option<Calibrator> {
        let spec = self.spec();
        if method.index_backed() && self.cfg.policy.mode == PolicyMode::Calibrated {
            Some(Calibrator::new(spec.layers, spec.q_heads, self.cfg.policy.calibration_steps))
        } else {
            None
        }
    }

    /// Fresh (empty) recent-query rings, one per (layer, q_head).
    fn empty_recent_rings(&self) -> Vec<Vec<Matrix>> {
        let spec = self.spec();
        (0..spec.layers)
            .map(|_| (0..spec.q_heads).map(|_| Matrix::zeros(0, spec.head_dim)).collect())
            .collect()
    }

    /// Exact causal attention for a prefill chunk (host side).
    #[allow(clippy::too_many_arguments)]
    fn prefill_attention(
        &self,
        caches: &[TieredKvCache],
        q: &[f32],
        chunk_start: usize,
        take: usize,
        q_heads: usize,
        kv_heads: usize,
        dh: usize,
    ) -> Result<Matrix> {
        let scale = self.scale();
        let group = q_heads / kv_heads;
        // Parallel over (local query index, head) pairs.
        let work: Vec<(usize, usize)> =
            (0..take).flat_map(|i| (0..q_heads).map(move |h| (i, h))).collect();
        let outs: Vec<Vec<f32>> = parallel::par_map(&work, |&(i, h)| {
            let kvh = h / group;
            let cache = &caches[kvh];
            let qoff = (i * q_heads + h) * dh;
            let qv = &q[qoff..qoff + dh];
            let upto = (chunk_start + i + 1) as u32;
            let ids: Vec<u32> = (0..upto).collect();
            attend_subset(qv, cache.keys(), cache.values(), &ids, scale).o
        });
        let mut attn = Matrix::zeros(PREFILL_CHUNK, q_heads * dh);
        for (w, o) in work.iter().zip(outs.iter()) {
            let (i, h) = *w;
            attn.row_mut(i)[h * dh..(h + 1) * dh].copy_from_slice(o);
        }
        Ok(attn)
    }

    /// Build host retrievers for an explicit method under a per-head
    /// policy (streaming heads get the index-free window view instead of
    /// the method's index). Also returns the per-(layer, kv_head) dense
    /// host key stores the retrievers index into — the engine keeps them
    /// to grow the searchable set on drains.
    fn build_retrievers_with(
        &self,
        caches: &[Vec<TieredKvCache>],
        q_history: &[Vec<Matrix>],
        method: Method,
        policy: &PolicyMap,
    ) -> Result<RetrieverBuild> {
        let spec = self.spec();
        let group = spec.group_size();
        // Copy the bits the parallel closure needs so it does not capture
        // `self` (Engine holds non-Sync PJRT handles).
        let scale = 1.0 / (spec.head_dim as f32).sqrt();
        let cfg = self.cfg.retrieval;
        let seed = self.cfg.seed;
        let mut retrievers = Vec::with_capacity(spec.layers);
        let mut groups: Vec<Vec<Arc<GroupShared>>> = Vec::with_capacity(spec.layers);
        for layer in 0..spec.layers {
            // ONE shared group state per kv head (Appendix C): the
            // segmented dense key copy plus the dense→absolute id map —
            // shared by every query head of the group instead of one
            // `Vec<u32>` per head.
            let shared: Vec<Arc<GroupShared>> = (0..spec.kv_heads)
                .map(|kvh| {
                    let cache = &caches[layer][kvh];
                    // The quantized scan tier (retrieval.quant.mode) is
                    // adopted here, at build time: every chunk the store
                    // ever grows — drains, tail merges, compactions —
                    // inherits the mode and gets its mirror built on the
                    // maintenance paths, never on the token path.
                    GroupShared::new(
                        KeyStore::from_matrix(cache.indexed_keys_matrix())
                            .with_quant(cfg.quant.mode),
                        cache.indexed_ids(),
                    )
                })
                .collect();
            groups.push(shared.clone());
            // Per-query-head retrievers build in parallel (index
            // construction is the expensive part).
            let heads: Vec<usize> = (0..spec.q_heads).collect();
            // Cap the training-query set: a strided subsample of the
            // prefill queries is statistically equivalent for index
            // construction and bounds the exact-KNN phase (§3.2 computes
            // it on the GPU; here it is host flops).
            const MAX_TRAIN_Q: usize = 512;
            let subsampled: Vec<Matrix> =
                q_history[layer].iter().map(|qh| qh.subsample_strided(MAX_TRAIN_Q)).collect();
            let built: Vec<Arc<dyn HostRetriever>> = parallel::par_map(&heads, |&h| {
                let kvh = h / group;
                let g = &shared[kvh];
                // The head's policy rides through `build_retriever_for_policy`
                // on every branch: a streaming head never builds an index,
                // empty group or not.
                let pol = policy.get(layer, h);
                if g.keys().rows() == 0 {
                    // Prompt fits entirely in the device static pattern:
                    // nothing is offloaded *yet*. Index methods fall back
                    // to an empty Flat index (it tolerates zero rows and
                    // accepts inserts), so overflow drains keep working
                    // once the window starts sliding — otherwise a short
                    // prompt with a long generation would accumulate an
                    // unbounded linearly-scanned overflow. Full keeps its
                    // exact all-host retriever; everything else degrades
                    // to the StreamingLLM empty set as before.
                    let fb = match method {
                        Method::Flat
                        | Method::Ivf
                        | Method::Hnsw
                        | Method::RetrievalAttention => Method::Flat,
                        Method::Full | Method::VllmLike => method,
                        _ => Method::StreamingLlm,
                    };
                    return Arc::from(build_retriever_for_policy(
                        fb,
                        RetrieverInputs {
                            group: g.clone(),
                            prefill_queries: &subsampled[h],
                            scale,
                            cfg: &cfg,
                            seed,
                        },
                        pol,
                    )) as Arc<dyn HostRetriever>;
                }
                let inp = RetrieverInputs {
                    group: g.clone(),
                    prefill_queries: &subsampled[h],
                    scale,
                    cfg: &cfg,
                    seed: seed ^ ((layer * 131 + h) as u64),
                };
                Arc::from(build_retriever_for_policy(method, inp, pol))
            });
            retrievers.push(built);
        }
        Ok((retrievers, groups))
    }

    /// One decode step (Algorithm 1). Feeds `token`, returns the next.
    ///
    /// Implemented as a single-slot wave: [`Engine::decode_wave`] is the
    /// primary decode path, and a one-item wave performs exactly the
    /// serial per-session computation.
    pub fn decode_step(&self, sess: &mut Session, token: u32) -> Result<DecodeOutput> {
        let mut wave = [WaveItem { sess, token }];
        match self.decode_wave(&mut wave).pop() {
            Some(r) => r,
            None => Err(anyhow::anyhow!("decode wave returned no result")),
        }
    }

    /// One fused decode step for a WAVE of sessions (the continuous-
    /// batching engine entry; Algorithm 1 per session).
    ///
    /// Every session advances exactly one token. Device calls (embed,
    /// QKV, static attention, FFN, lm_head) stay serial on this thread —
    /// the runtime handles are `!Send` — but the host-side phases that
    /// dominate long-context decode are **fused across sessions**:
    ///
    /// * candidate retrieval fans every (session, head) pair of the wave
    ///   into one `par_map` pool (shared batched kernel dispatches);
    /// * the host attention read scores each (session, GQA-group) with
    ///   the multi-query gather [`attend_group_mq`] (each candidate key
    ///   row is read once per group, not once per head) and prefetches
    ///   the next slot's first candidate rows while the current group's
    ///   softmax is in flight (wave-style overlap).
    ///
    /// **Bit-identity invariant**: fusion only reorders *independent*
    /// per-session/per-head work whose per-item computation is unchanged,
    /// and `par_map` is order-preserving — so a wave of N sessions
    /// produces exactly the tokens each session would produce decoding
    /// alone (`tests/scheduler.rs` locks this in). Per-session index
    /// maintenance stays serialized per session at the end of the wave.
    ///
    /// Errors are isolated per slot: a failing session yields `Err` in
    /// its result position and drops out of later phases; the rest of
    /// the wave completes. **Panics in the serial per-slot phases are
    /// contained the same way** ([`contained`]): the panicking slot
    /// becomes its `Err` and the survivors — whose per-item computation
    /// is untouched by construction — finish bit-identically. Panics
    /// inside the FUSED phases (`par_map` pools) have no per-slot
    /// attribution and propagate; the coordinator's whole-wave backstop
    /// catches those. Fused-phase wall time is attributed to each live
    /// session's breakdown in equal shares.
    ///
    /// Fault-injection site `wave.decode` fires in the per-slot embed
    /// phase (the first serial phase), so an injected error or panic
    /// lands on exactly one deterministic slot.
    pub fn decode_wave(&self, items: &mut [WaveItem]) -> Vec<Result<DecodeOutput>> {
        let n = items.len();
        let spec = self.spec().clone();
        let scale = self.scale();
        let group = spec.group_size();
        let dh = spec.head_dim;
        let retrieval_k = &self.cfg.retrieval;

        let mut errs: Vec<Option<anyhow::Error>> = (0..n).map(|_| None).collect();
        let mut bds: Vec<PhaseBreakdown> = vec![PhaseBreakdown::default(); n];
        let mut xs: Vec<Vec<f32>> = vec![Vec::new(); n];
        let mut qs: Vec<Vec<f32>> = vec![Vec::new(); n];
        // Previous layer's query vectors (InfiniGen-style speculation).
        let mut prev_qs: Vec<Option<Vec<f32>>> = (0..n).map(|_| None).collect();
        let mut o_devs: Vec<Vec<f32>> = vec![Vec::new(); n];
        let mut lse_devs: Vec<Vec<f32>> = vec![Vec::new(); n];
        // Wave-level registry accounting, flushed once per wave (never
        // per token) so the hot loop stays free of registry lookups.
        let mut scanned_wave = 0u64;
        let mut tokens_emitted = 0u64;

        // Embed (serial per slot).
        for (s, it) in items.iter_mut().enumerate() {
            // Per-head id scratch, reused across layers and tokens (sized
            // lazily so deserialized/forked sessions pick it up too).
            if it.sess.host_ids.len() < spec.q_heads {
                it.sess.host_ids.resize_with(spec.q_heads, Vec::new);
            }
            let t = Stopwatch::start();
            let r = contained("wave embed step", || -> Result<Vec<f32>> {
                crate::util::failpoint::trigger("wave.decode")?;
                let pos = crate::model::position_code(&spec, it.sess.len);
                let id_b = self.rt.upload_i32(&[it.token as i32], &[1])?;
                let pos_b = self.rt.upload_f32(&pos, &[1, spec.d_model])?;
                let outs = self.rt.exec_b("embed_b1", &[&self.lits.table, &id_b, &pos_b])?;
                literal_to_f32(&outs[0])
            });
            let secs = t.stop_into(&mut bds[s].other);
            telemetry::span_record(&mut it.sess.spans, Phase::Embed, t.started(), secs, s as u64);
            match r {
                Ok(x) => xs[s] = x,
                Err(e) => errs[s] = Some(e),
            }
        }

        for layer in 0..spec.layers {
            let ll = &self.lits.layers[layer];
            // QKV projection + KV append + device partial attention over W
            // (device round-trips: serial per live slot).
            for (s, it) in items.iter_mut().enumerate() {
                if errs[s].is_some() {
                    continue;
                }
                let t = Stopwatch::start();
                let r = contained("wave qkv step", || -> Result<Vec<f32>> {
                    let x_b = self.rt.upload_f32(&xs[s], &[1, spec.d_model])?;
                    let outs =
                        self.rt.exec_b("qkv_b1", &[&x_b, &ll.g, &ll.wq, &ll.wk, &ll.wv])?;
                    let q = literal_to_f32(&outs[0])?; // [H, dh] (B=1 flattened)
                    let k = literal_to_f32(&outs[1])?;
                    let v = literal_to_f32(&outs[2])?;
                    for kvh in 0..spec.kv_heads {
                        let off = kvh * dh;
                        it.sess.caches[layer][kvh].append(&k[off..off + dh], &v[off..off + dh]);
                    }
                    // Record decode queries: the attention-aware training
                    // side for online index inserts (RoarGraph wires
                    // drained keys with them).
                    let recent_cap = retrieval_k.maintenance.recent_queries;
                    for h in 0..spec.q_heads {
                        push_recent(
                            &mut it.sess.recent_q[layer][h],
                            &q[h * dh..(h + 1) * dh],
                            recent_cap,
                        );
                    }
                    Ok(q)
                });
                let secs = t.stop_into(&mut bds[s].other);
                telemetry::span_record(&mut it.sess.spans, Phase::Qkv, t.started(), secs, s as u64);
                let q = match r {
                    Ok(q) => q,
                    Err(e) => {
                        errs[s] = Some(e);
                        continue;
                    }
                };
                let t = Stopwatch::start();
                match contained("wave device-partial step", || {
                    self.device_partial(&it.sess.caches[layer], &q, &spec)
                }) {
                    Ok((o, l)) => {
                        o_devs[s] = o;
                        lse_devs[s] = l;
                        qs[s] = q;
                    }
                    Err(e) => errs[s] = Some(e),
                }
                let secs = t.stop_into(&mut bds[s].attention);
                telemetry::span_record(
                    &mut it.sess.spans,
                    Phase::DeviceAttn,
                    t.started(),
                    secs,
                    s as u64,
                );
            }

            let live: Vec<usize> = (0..n).filter(|&s| errs[s].is_none()).collect();
            if live.is_empty() {
                break;
            }

            // Host retrieval (the Table 5 "vector search" phase), FUSED:
            // every (session, head) pair of the wave shares one batched
            // fan-out — cross-session candidate scoring in shared kernel
            // dispatches instead of per-session pools.
            let budget = retrieval_k.budget.k_for_layer(layer, spec.layers);
            let t = Stopwatch::start();
            let mut retrieved_all: Vec<Vec<crate::baselines::Retrieval>> =
                (0..n).map(|_| Vec::new()).collect();
            {
                let sess_refs: Vec<&Session> = items.iter().map(|it| &*it.sess).collect();
                let ret_work: Vec<(usize, usize)> = live
                    .iter()
                    .flat_map(|&s| (0..spec.q_heads).map(move |h| (s, h)))
                    .collect();
                let flat: Vec<crate::baselines::Retrieval> =
                    parallel::par_map(&ret_work, |&(s, h)| {
                        let sess = sess_refs[s];
                        let retr = &sess.retrievers[layer][h];
                        let spec_q = if retr.speculates_from_previous_layer() {
                            prev_qs[s].as_deref().unwrap_or(&qs[s])
                        } else {
                            &qs[s]
                        };
                        retr.retrieve(&spec_q[h * dh..(h + 1) * dh], budget)
                    });
                for (&(s, _h), r) in ret_work.iter().zip(flat) {
                    retrieved_all[s].push(r);
                }
            }
            let share = t.elapsed_s() / live.len() as f64;
            for &s in &live {
                bds[s].search += share;
                let sess = &mut *items[s].sess;
                telemetry::span_record(&mut sess.spans, Phase::Retrieval, t.started(), share, s as u64);
                for r in &retrieved_all[s] {
                    sess.scanned_total += r.scanned as u64;
                    scanned_wave += r.scanned as u64;
                    sess.retrievals += 1;
                }
            }

            // Per-slot candidate-set assembly into session scratch (no
            // `retrieved[h].ids` clone per head × layer × token; overflow
            // ids materialised once per GQA group).
            for &s in &live {
                let t = Stopwatch::start();
                let sess = &mut *items[s].sess;
                let overflow: Vec<Vec<u32>> = (0..spec.kv_heads)
                    .map(|kvh| sess.caches[layer][kvh].overflow_ids())
                    .collect();
                let layer_caches = &sess.caches[layer];
                parallel::par_zip_mut(
                    &mut sess.host_ids[..spec.q_heads],
                    &retrieved_all[s],
                    |h, ids, r| {
                        let cache = &layer_caches[h / group];
                        ids.clear();
                        ids.extend_from_slice(&r.ids);
                        // The overflow buffer (window slid past it, not yet
                        // in the index) is attended exactly; the
                        // maintenance worker drains it into the index on a
                        // watermark, so it stays bounded no matter how long
                        // the generation runs.
                        ids.extend_from_slice(&overflow[h / group]);
                        // Dedup: the worker's index swap can land
                        // mid-window, so a freshly drained token may
                        // surface both from retrieval and from the
                        // not-yet-advanced overflow scan — attending it
                        // twice would double its softmax weight. Retired
                        // (evicted) tokens are dropped here synchronously;
                        // their index tombstone is async reclamation.
                        ids.sort_unstable();
                        ids.dedup();
                        ids.retain(|&id| !cache.is_retired(id as usize));
                    },
                );
                let secs = t.stop_into(&mut bds[s].attention);
                telemetry::span_record(&mut sess.spans, Phase::Candidates, t.started(), secs, s as u64);
            }

            // Host partial attention, FUSED: one multi-query gather per
            // (session, GQA group) — each candidate key row is read once
            // per group instead of once per head — with the NEXT slot's
            // first candidate rows prefetched while this group's softmax
            // is in flight (the wave-overlap read-ahead).
            let t = Stopwatch::start();
            let att_work: Vec<(usize, usize)> = live
                .iter()
                .flat_map(|&s| (0..spec.kv_heads).map(move |kvh| (s, kvh)))
                .collect();
            let parts: Vec<Vec<PartialAttention>> = {
                let sess_refs: Vec<&Session> = items.iter().map(|it| &*it.sess).collect();
                let widx: Vec<usize> = (0..att_work.len()).collect();
                parallel::par_map(&widx, |&i| {
                    let (s, kvh) = att_work[i];
                    let sess = sess_refs[s];
                    let cache = &sess.caches[layer][kvh];
                    // Read-ahead: touch the next (session, group) slot's
                    // first candidate key row so its cache line is in
                    // flight during this group's score+softmax (safe hint;
                    // never dereferenced).
                    if let Some(&(s2, kvh2)) = att_work.get(i + 1) {
                        let sess2 = sess_refs[s2];
                        let keys2 = sess2.caches[layer][kvh2].keys();
                        if let Some(&id) = sess2
                            .host_ids
                            .get(kvh2 * group)
                            .and_then(|ids| ids.first())
                        {
                            if let Some(row0) = keys2.as_slice().get(id as usize * keys2.cols())
                            {
                                kernel::prefetch(row0 as *const f32);
                            }
                        }
                    }
                    let per_head: Vec<&[u32]> = (0..group)
                        .map(|g| sess.host_ids[kvh * group + g].as_slice())
                        .collect();
                    let qg = &qs[s][kvh * group * dh..(kvh + 1) * group * dh];
                    attend_group_mq(qg, cache.keys(), cache.values(), &per_head, scale)
                })
            };
            let share = t.elapsed_s() / live.len() as f64;
            for &s in &live {
                bds[s].attention += share;
                telemetry::span_record(
                    &mut items[s].sess.spans,
                    Phase::HostAttn,
                    t.started(),
                    share,
                    s as u64,
                );
            }
            let mut slot_parts: Vec<Vec<Vec<PartialAttention>>> =
                (0..n).map(|_| Vec::new()).collect();
            for ((s, _kvh), p) in att_work.into_iter().zip(parts) {
                slot_parts[s].push(p);
            }

            // Exact γ-combine (Eq. 4/5) + output projection + FFN
            // (device round-trips: serial per live slot).
            for &s in &live {
                let t = Stopwatch::start();
                let mut attn = vec![0.0f32; spec.q_heads * dh];
                for h in 0..spec.q_heads {
                    let p = &slot_parts[s][h / group][h % group];
                    // The profiling signal is free: the two partials'
                    // LSEs in hand here ARE the device-span-vs-rest mass
                    // split the policy calibration needs (DuoAttention's
                    // sink+window score, no extra attention pass).
                    if let Some(c) = items[s].sess.calib.as_mut() {
                        c.record(layer, h, Calibrator::span_mass(lse_devs[s][h], p.lse));
                    }
                    combine_into(
                        &[
                            (&o_devs[s][h * dh..(h + 1) * dh], lse_devs[s][h]),
                            (p.o.as_slice(), p.lse),
                        ],
                        &mut attn[h * dh..(h + 1) * dh],
                    );
                }
                let secs = t.stop_into(&mut bds[s].attention);
                telemetry::span_record(
                    &mut items[s].sess.spans,
                    Phase::GammaCombine,
                    t.started(),
                    secs,
                    s as u64,
                );
                let t = Stopwatch::start();
                let r = contained("wave post/ffn step", || -> Result<Vec<f32>> {
                    let x_b = self.rt.upload_f32(&xs[s], &[1, spec.d_model])?;
                    let attn_b = self.rt.upload_f32(&attn, &[1, spec.q_heads * dh])?;
                    let outs = self.rt.exec_b(
                        "post_b1",
                        &[&x_b, &attn_b, &ll.wo, &ll.g2, &ll.w1, &ll.w3, &ll.w2],
                    )?;
                    literal_to_f32(&outs[0])
                });
                let secs = t.stop_into(&mut bds[s].other);
                telemetry::span_record(
                    &mut items[s].sess.spans,
                    Phase::Ffn,
                    t.started(),
                    secs,
                    s as u64,
                );
                match r {
                    Ok(x) => {
                        xs[s] = x;
                        prev_qs[s] = Some(std::mem::take(&mut qs[s]));
                    }
                    Err(e) => errs[s] = Some(e),
                }
            }
        }

        // LM head + greedy sampling, then per-session index maintenance —
        // maintenance stays serialized PER SESSION (each session's worker
        // protocol and flush order are untouched by the wave fusion).
        let mut out: Vec<Result<DecodeOutput>> = Vec::with_capacity(n);
        for (s, it) in items.iter_mut().enumerate() {
            if let Some(e) = errs[s].take() {
                out.push(Err(e));
                continue;
            }
            let t = Stopwatch::start();
            let next = match contained("wave lm-head step", || self.lm_head(&xs[s])) {
                Ok(tok) => tok,
                Err(e) => {
                    out.push(Err(e));
                    continue;
                }
            };
            it.sess.x_last = std::mem::take(&mut xs[s]);
            it.sess.len += 1;
            tokens_emitted += 1;
            let secs = t.stop_into(&mut bds[s].other);
            telemetry::span_record(&mut it.sess.spans, Phase::Ffn, t.started(), secs, s as u64);
            // Calibration bookkeeping: one profiling step accumulated
            // across all layers; once the window closes, commit the
            // verdict (streaming heads release their index for the group
            // window view) before this step's maintenance runs.
            if let Some(c) = it.sess.calib.as_mut() {
                if c.end_step() {
                    let decided = c.decide(&self.cfg.policy);
                    it.sess.calib = None;
                    self.apply_policy(it.sess, &decided);
                }
            }
            // Online index maintenance: drain overflow buffers that
            // crossed the watermark into the ANN indexes (batched, fanned
            // out per GQA group via util::parallel).
            let t = Stopwatch::start();
            self.maintain_indexes(it.sess);
            let secs = t.stop_into(&mut bds[s].maintenance);
            telemetry::span_record(
                &mut it.sess.spans,
                Phase::Maintenance,
                t.started(),
                secs,
                s as u64,
            );
            out.push(Ok(DecodeOutput { token: next, breakdown: std::mem::take(&mut bds[s]) }));
        }
        if tokens_emitted > 0 || scanned_wave > 0 {
            let reg = telemetry::registry();
            reg.counter("engine.tokens_total").add(tokens_emitted);
            // Quantized-vs-exact scored-key attribution: whether this
            // wave's scans went through the quantized scan tier is a
            // config-level fact, not a per-key one.
            let scores = if self.cfg.retrieval.quant.mode == kernel::QuantMode::Off {
                "kernel.scores_exact_total"
            } else {
                "kernel.scores_quantized_total"
            };
            reg.counter(scores).add(scanned_wave);
        }
        out
    }

    /// Commit a decided policy to a live session: every head flipping
    /// Retrieval→Streaming drops its index in favor of the group window
    /// view, and the released index heap is accounted in
    /// `index_bytes_avoided`. Flips never go the other way — `decide`
    /// honors the same override lists the build did, so a head that
    /// started streaming stays streaming — which means no index is ever
    /// (re)built here. In-flight maintenance holding the old retriever's
    /// `Arc` completes harmlessly against it; the group-level store/map
    /// growth it publishes is what the streaming view reads anyway.
    fn apply_policy(&self, sess: &mut Session, decided: &PolicyMap) {
        let spec = self.spec();
        let group_size = spec.group_size();
        for layer in 0..spec.layers {
            for h in 0..spec.q_heads {
                let pol = decided.get(layer, h);
                if let HeadPolicy::Streaming { sinks, window } = pol {
                    if sess.policy.get(layer, h).is_streaming() {
                        continue;
                    }
                    sess.index_bytes_avoided +=
                        sess.retrievers[layer][h].memory_bytes() as u64;
                    let g = sess.groups[layer][h / group_size].clone();
                    sess.retrievers[layer][h] =
                        Arc::new(StreamingRetriever::new(g, sinks, window));
                    sess.policy.set(layer, h, pol);
                }
            }
        }
        let frac = sess.streaming_fraction();
        let reg = telemetry::registry();
        reg.gauge("policy.streaming_fraction").set(frac);
        reg.gauge("policy.index_bytes_avoided").set_u64(sess.index_bytes_avoided);
        telemetry::flightrec(
            "policy.decided",
            format!(
                "streaming_fraction={frac:.3} index_bytes_avoided={}",
                sess.index_bytes_avoided
            ),
        );
    }

    /// Online maintenance: apply completed background work, then enqueue
    /// (or, with `async_worker` off, run inline) one job per (layer,
    /// kv-head) group that needs it:
    ///
    /// * **Drain** — overflow past the watermark is snapshotted (key rows
    ///   + absolute ids + per-head recent queries) and handed to the
    ///   worker, which grows the group's shared segmented store/id map
    ///   and double-buffer-swaps every head's index. The cache's indexed
    ///   boundary advances only when the completion is applied, so the
    ///   overflow scan keeps covering the batch until the index provably
    ///   does (the decode-path dedup prevents double attention in the
    ///   swap-to-completion window).
    /// * **Evict** — once a group's live indexed tier exceeds
    ///   `eviction.max_indexed`, the oldest tokens are retired from
    ///   attention synchronously and tombstoned in the indexes
    ///   asynchronously (StreamingLLM-style window retirement over host
    ///   memory).
    /// * **Compact** — once a group's index tombstones exceed
    ///   `eviction.reclaim_ratio` × live rows, a reclamation epoch
    ///   physically drops the dead rows: compacted store + id map under a
    ///   bumped store generation, dense ids remapped in all four index
    ///   families. This is what turns bounded *attention* into bounded
    ///   *memory* for indefinitely long streaming sessions.
    fn maintain_indexes(&self, sess: &mut Session) {
        let mcfg = self.cfg.retrieval.maintenance;
        let ecfg = self.cfg.retrieval.eviction;
        let spec = self.spec();
        let group = spec.group_size();
        // Guard on the SESSION's method, not the engine's: a session built
        // for a different method must not inherit StreamingLLM's
        // token-discard drain semantics.
        let method = sess.method;
        let streaming = method == Method::StreamingLlm;

        sess.apply_completions();

        // `drain_watermark == 0` disables *index* maintenance. StreamingLLM
        // sessions still drop their overflow every step: that is the
        // method's semantics (sink + window only), and it must not change
        // with a performance knob. Reclamation keeps the loop alive only
        // for sessions that actually tombstoned something (`had_removals`
        // covers the truncation-without-eviction case) — reclaim_enabled
        // alone must not defeat the early return, since it defaults on.
        if (!mcfg.enabled()
            && !streaming
            && !ecfg.enabled()
            && !(ecfg.reclaim_enabled() && sess.had_removals))
            || sess.retrievers.is_empty()
        {
            return;
        }

        for layer in 0..spec.layers {
            for kvh in 0..spec.kv_heads {
                if sess.maint.inflight.contains(&(layer, kvh)) {
                    continue;
                }
                // Length-only check on the per-token path; the id list is
                // materialised only for groups that actually drain.
                let over_len = sess.caches[layer][kvh].overflow_len();
                if over_len > 0 {
                    // Every head of the group must accept inserts; a
                    // discarding retriever (StreamingLLM semantics,
                    // including the empty-host-set fallback a static
                    // baseline degrades to) may only swallow tokens when
                    // StreamingLLM is the session's method — other methods
                    // keep their exact overflow scan instead.
                    let ok = (0..group).all(|g| {
                        let r = &sess.retrievers[layer][kvh * group + g];
                        r.supports_insert() && (streaming || !r.discards_inserts())
                    });
                    let all_discard = ok
                        && (0..group)
                            .all(|g| sess.retrievers[layer][kvh * group + g].discards_inserts());
                    if ok && all_discard {
                        // Discarding groups drop tokens the moment they
                        // leave the window: pure StreamingLLM semantics,
                        // watermark-free and synchronous (no index work).
                        sess.caches[layer][kvh].advance_indexed(usize::MAX);
                        sess.drained_tokens += over_len as u64;
                        sess.drains += 1;
                    } else if ok && mcfg.enabled() && over_len >= mcfg.drain_watermark {
                        if let Some(job) = self.snapshot_drain(sess, layer, kvh, group) {
                            if mcfg.async_worker {
                                sess.maint.inflight.insert((layer, kvh));
                                sess.maint.submit(Job::Drain(job));
                            } else {
                                let done = run_drain(&job);
                                sess.apply_done(&done);
                            }
                        }
                    }
                }
                // StreamingLLM-style window retirement over the indexed
                // tier: retire the oldest tokens from attention now,
                // tombstone them in the indexes on the worker.
                if ecfg.enabled() {
                    let live = sess.caches[layer][kvh].indexed_len();
                    let removable = live > ecfg.max_indexed
                        && (0..group)
                            .all(|g| sess.retrievers[layer][kvh * group + g].supports_remove());
                    if removable {
                        let n = live - ecfg.max_indexed;
                        let ids = sess.caches[layer][kvh].retire_oldest_indexed(n);
                        if !ids.is_empty() {
                            sess.had_removals = true;
                            sess.maint.stats.evicted_tokens += ids.len() as u64;
                            let heads: Vec<Arc<dyn HostRetriever>> = (0..group)
                                .map(|g| sess.retrievers[layer][kvh * group + g].clone())
                                .collect();
                            let job = EvictJob {
                                layer,
                                kvh,
                                ids,
                                heads,
                                group: sess.groups[layer][kvh].clone(),
                            };
                            if mcfg.async_worker {
                                sess.maint.submit(Job::Evict(job));
                            } else {
                                let done = run_evict(&job);
                                sess.apply_done(&done);
                            }
                        }
                    }
                }
                // Reclamation epoch (the tentpole): once the tombstones
                // accumulated in this group's indexes exceed
                // `reclaim_ratio` × the live row count, run a
                // `Job::Compact` — compacted store + id map under a
                // bumped store generation, dense ids remapped in every
                // head's index. Gated on the in-flight set: a drain
                // snapshot taken before the remap would carry pre-remap
                // dense contracts, so the two never overlap for a group
                // (the worker queue serializes everything else). The
                // `had_removals` flag keeps the per-token cost at zero for
                // sessions that never evicted or truncated; otherwise the
                // poll is ONE front load per group.
                if ecfg.reclaim_enabled()
                    && sess.had_removals
                    && !sess.maint.inflight.contains(&(layer, kvh))
                {
                    // First head that REPORTS counts speaks for the group
                    // (heads with no dense state — streaming windows —
                    // return `None` and must not mask their siblings'
                    // tombstones).
                    let (live, dead) = (0..group)
                        .find_map(|g| {
                            sess.retrievers[layer][kvh * group + g].reclaim_counts()
                        })
                        .unwrap_or((0, 0));
                    let claimable = live > 0
                        && dead > 0
                        && (dead as f64) >= (ecfg.reclaim_ratio as f64) * (live as f64)
                        && (0..group)
                            .all(|g| sess.retrievers[layer][kvh * group + g].supports_reclaim());
                    if claimable {
                        let heads: Vec<Arc<dyn HostRetriever>> = (0..group)
                            .map(|g| sess.retrievers[layer][kvh * group + g].clone())
                            .collect();
                        let job = CompactJob {
                            layer,
                            kvh,
                            heads,
                            group: sess.groups[layer][kvh].clone(),
                        };
                        if mcfg.async_worker {
                            sess.maint.inflight.insert((layer, kvh));
                            sess.maint.submit(Job::Compact(job));
                        } else {
                            let done = run_compact(&job);
                            sess.apply_done(&done);
                        }
                    }
                }
            }
        }
    }

    /// Snapshot one group's overflow batch into an owned [`DrainJob`]
    /// (key rows, absolute ids, per-head recent-query context). Copies
    /// only the batch — the immutable prefix of the group store is shared
    /// segment-wise, never recopied.
    fn snapshot_drain(
        &self,
        sess: &Session,
        layer: usize,
        kvh: usize,
        group: usize,
    ) -> Option<DrainJob> {
        let mcfg = self.cfg.retrieval.maintenance;
        let cache = &sess.caches[layer][kvh];
        let over = cache.overflow_ids();
        let upto = over.last().map(|&x| x as usize + 1)?;
        let heads: Vec<Arc<dyn HostRetriever>> =
            (0..group).map(|g| sess.retrievers[layer][kvh * group + g].clone()).collect();
        // Grow the group's dense store by the overflow key rows — but only
        // when some head actually reads it (AllRetriever tracks ids alone,
        // so Full/vLLM drains skip the copy).
        let grow_store = heads.iter().any(|r| r.needs_store());
        let rows = if grow_store {
            let mut m = Matrix::zeros(0, cache.dim());
            for &id in &over {
                m.push_row(cache.key(id as usize));
            }
            m
        } else {
            Matrix::zeros(0, cache.dim())
        };
        // The ring is compacted lazily (up to 2x cap between compactions);
        // enforce the configured budget exactly at the point where each
        // query costs a graph search.
        let queries: Vec<Option<Matrix>> = (0..group)
            .map(|g| {
                let ring = &sess.recent_q[layer][kvh * group + g];
                if mcfg.recent_queries == 0 || ring.rows() == 0 {
                    None
                } else {
                    Some(ring.keep_last_rows(mcfg.recent_queries))
                }
            })
            .collect();
        Some(DrainJob {
            layer,
            kvh,
            rows,
            ids: over,
            upto,
            grow_store,
            heads,
            queries,
            group: sess.groups[layer][kvh].clone(),
        })
    }

    /// Device-side partial attention over the static set via the
    /// `static_attn` artifact (Pallas flash_decode).
    fn device_partial(
        &self,
        caches: &[TieredKvCache],
        q: &[f32],
        spec: &crate::runtime::manifest::SpecMeta,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let s = spec.static_len;
        let dh = spec.head_dim;
        let dev_ids = caches[0].device_ids();
        let valid = dev_ids.len().min(s);
        let mut keys = vec![0.0f32; s * spec.kv_heads * dh];
        let mut values = vec![0.0f32; s * spec.kv_heads * dh];
        let mut mask = vec![-1.0e30f32; s];
        for (slot, &id) in dev_ids.iter().take(valid).enumerate() {
            mask[slot] = 0.0;
            for kvh in 0..spec.kv_heads {
                let off = (slot * spec.kv_heads + kvh) * dh;
                keys[off..off + dh].copy_from_slice(caches[kvh].key(id as usize));
                values[off..off + dh].copy_from_slice(caches[kvh].value(id as usize));
            }
        }
        let q_b = self.rt.upload_f32(q, &[spec.q_heads, dh])?;
        let k_b = self.rt.upload_f32(&keys, &[s, spec.kv_heads, dh])?;
        let v_b = self.rt.upload_f32(&values, &[s, spec.kv_heads, dh])?;
        let m_b = self.rt.upload_f32(&mask, &[s])?;
        let outs = self.rt.exec_b("static_attn", &[&q_b, &k_b, &v_b, &m_b])?;
        Ok((literal_to_f32(&outs[0])?, literal_to_f32(&outs[1])?))
    }

    /// LM head + greedy sampling over one hidden state.
    fn lm_head(&self, x: &[f32]) -> Result<u32> {
        let spec = self.spec();
        let x_b = self.rt.upload_f32(x, &[1, spec.d_model])?;
        let outs = self.rt.exec_b("lm_head_b1", &[&x_b, &self.lits.gf, &self.lits.wu])?;
        let logits = literal_to_f32(&outs[0])?;
        Ok(crate::tensor::argtopk(&logits, 1)[0] as u32)
    }

    /// First generated token: lm_head over the prefill's last hidden state.
    pub fn first_token(&self, sess: &Session) -> Result<u32> {
        self.lm_head(&sess.x_last)
    }

    /// Generate `max_tokens` greedily from a freshly prefilled session:
    /// the first token comes from the prompt's last hidden state, each
    /// subsequent one from a decode step. Returns the tokens and the
    /// summed decode phase breakdown. Pending background maintenance is
    /// flushed before returning, so the session's boundaries and counters
    /// are quiescent for the caller.
    pub fn generate(
        &self,
        sess: &mut Session,
        max_tokens: usize,
    ) -> Result<(Vec<u32>, PhaseBreakdown)> {
        let mut tokens = Vec::with_capacity(max_tokens);
        let mut total = PhaseBreakdown::default();
        let mut cur = self.first_token(sess)?;
        tokens.push(cur);
        while tokens.len() < max_tokens {
            let out = self.decode_step(sess, cur)?;
            total.add(&out.breakdown);
            tokens.push(out.token);
            cur = out.token;
        }
        // Quiesce: apply in-flight completions, run one more maintenance
        // pass for groups whose drain was skipped while in flight, and
        // apply that too — post-generate overflow is strictly below the
        // watermark regardless of worker scheduling.
        sess.flush_maintenance();
        self.maintain_indexes(sess);
        sess.flush_maintenance();
        Ok((tokens, total))
    }
}

impl Session {
    /// Mean scanned keys per retrieval (Fig 6 x-axis).
    pub fn mean_scanned(&self) -> f64 {
        if self.retrievals == 0 {
            0.0
        } else {
            self.scanned_total as f64 / self.retrievals as f64
        }
    }

    /// Clone the prefill state (caches, query history, hidden) *without*
    /// retrievers — used to evaluate many methods against one prefill
    /// (prefill is method-independent: it is always exact attention).
    pub fn fork_state(&self) -> Session {
        Session {
            method: self.method,
            caches: self.caches.clone(),
            q_history: self.q_history.clone(),
            retrievers: Vec::new(),
            groups: Vec::new(),
            maint: MaintenanceState::new(),
            recent_q: self.recent_q.clone(),
            host_ids: Vec::new(),
            x_last: self.x_last.clone(),
            len: self.len,
            scanned_total: 0,
            retrievals: 0,
            drained_tokens: 0,
            drains: 0,
            had_removals: false,
            // The assignment and any mid-flight profiling carry over (the
            // fork continues the same text); released-bytes accounting is
            // per-session and starts at zero.
            policy: self.policy.clone(),
            calib: self.calib.clone(),
            index_bytes_avoided: 0,
            spans: SpanAcc::default(),
        }
    }

    /// Fraction of query heads on the streaming tier (the done-event /
    /// bench metric).
    pub fn streaming_fraction(&self) -> f64 {
        self.policy.streaming_fraction()
    }

    /// Snapshot of a group's shared dense key store.
    pub fn host_store(&self, layer: usize, kvh: usize) -> crate::index::KeyStore {
        self.groups[layer][kvh].keys()
    }

    /// Apply one maintenance completion: drains advance the cache's
    /// indexed boundary (dropping those tokens from the overflow scan)
    /// and bump the drain counters; evictions only feed the stats (the
    /// retire boundary moved synchronously at enqueue time).
    pub fn apply_done(&mut self, d: &Done) {
        self.maint.stats.swaps += 1;
        self.maint.stats.swap_s_total += d.swap_s;
        match d.kind {
            DoneKind::Drained { upto, count } => {
                // Only a drain completion may clear the group's in-flight
                // marker: evictions never set it, and clearing it early
                // would let a second overlapping drain re-snapshot the
                // same overflow while the first is still executing.
                self.maint.inflight.remove(&(d.layer, d.kvh));
                if d.ok {
                    self.caches[d.layer][d.kvh].advance_indexed(upto);
                    self.drained_tokens += count;
                    self.drains += 1;
                }
            }
            DoneKind::Evicted { .. } => {}
            DoneKind::Compacted { dropped } => {
                // Compactions hold the in-flight marker exactly like
                // drains (they must not overlap a drain snapshot).
                self.maint.inflight.remove(&(d.layer, d.kvh));
                if d.ok {
                    self.maint.stats.reclaims += 1;
                    self.maint.stats.reclaimed_rows += dropped;
                }
            }
        }
    }

    /// Non-blocking: apply whatever the worker has finished so far.
    pub fn apply_completions(&mut self) {
        let dones = self.maint.poll();
        for d in dones {
            self.apply_done(&d);
        }
    }

    /// Block until the worker queue is empty and apply every completion.
    pub fn flush_maintenance(&mut self) {
        let dones = self.maint.flush();
        for d in dones {
            self.apply_done(&d);
        }
    }

    /// Flush, stop the worker thread, and apply the final completions.
    /// The concurrency suite uses this to assert exact reconciliation:
    /// after shutdown, drain counters equal the advanced boundaries and
    /// every head's index length matches its cache's indexed tier.
    pub fn shutdown_maintenance(&mut self) {
        let dones = self.maint.shutdown();
        for d in dones {
            self.apply_done(&d);
        }
    }

    /// Tombstoned fraction across every head's index (0.0 when nothing
    /// is indexed — baselines without an index report no tombstones).
    pub fn tombstone_ratio(&self) -> f64 {
        let (mut dead, mut total) = (0usize, 0usize);
        for layer in &self.retrievers {
            for r in layer {
                if let Some(live) = r.indexed_len() {
                    dead += r.tombstones();
                    total += live + r.tombstones();
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            dead as f64 / total as f64
        }
    }

    /// Heap bytes of the host retrieval state: per-head index structures
    /// plus the group-shared id maps and key stores (f32 payload + chunk
    /// table) counted ONCE per GQA group — the Appendix C accounting the
    /// memory regression test locks in.
    pub fn index_memory_bytes(&self) -> usize {
        let mut total = 0usize;
        for layer in &self.retrievers {
            for r in layer {
                total += r.memory_bytes();
            }
        }
        for layer in &self.groups {
            for g in layer {
                total += g.map_bytes() + g.store_bytes();
            }
        }
        total
    }
}

impl Session {
    /// Approximate heap bytes of the whole session state (KV caches,
    /// query histories, group stores/maps, index structures): the resident
    /// budget currency of the `serving.session_cache` registry.
    pub fn state_bytes(&self) -> usize {
        let mut total = self.x_last.len() * 4;
        for layer in &self.caches {
            for c in layer {
                total += c.len() * 2 * c.dim() * 4;
            }
        }
        for layer in &self.q_history {
            for m in layer {
                total += m.as_slice().len() * 4;
            }
        }
        for layer in &self.recent_q {
            for m in layer {
                total += m.as_slice().len() * 4;
            }
        }
        total + self.index_memory_bytes()
    }
}

impl Engine {
    /// Serialize `sess` into the versioned binary snapshot format (see
    /// [`crate::store`]): pending maintenance is flushed first so the
    /// image is a **single-generation, replay-free** structural copy —
    /// KV caches with their raw tier boundaries, per-group segmented
    /// stores + generation-stamped id maps, and every head's index
    /// family serialized structurally. Restoring it re-pays neither the
    /// prefill nor any index build, and searches over the restored
    /// session are bit-identical. Returns the bytes written.
    pub fn snapshot_session(
        &self,
        sess: &mut Session,
        out: &mut dyn std::io::Write,
    ) -> Result<u64> {
        self.snapshot_session_versioned(sess, out, crate::store::VERSION)
    }

    /// [`Engine::snapshot_session`] at an explicit format version. The
    /// only other supported version is the previous one (v2, no
    /// checksummed footer) — kept writable so the cross-version restore
    /// path stays testable against bytes this build produced itself.
    pub fn snapshot_session_versioned(
        &self,
        sess: &mut Session,
        out: &mut dyn std::io::Write,
        version: u32,
    ) -> Result<u64> {
        anyhow::ensure!(
            version == crate::store::VERSION || version == crate::store::V2,
            "cannot write snapshot format v{version}"
        );
        crate::util::failpoint::trigger("codec.snapshot")?;
        let t = Stopwatch::start();
        sess.flush_maintenance();
        let spec = self.spec().clone();
        anyhow::ensure!(
            sess.retrievers.len() == spec.layers && sess.groups.len() == spec.layers,
            "snapshot requires a fully built session (retrievers + groups)"
        );
        let mut w = crate::store::codec::SnapWriter::new(out);
        w.raw(crate::store::MAGIC)?;
        w.u32(version)?;
        // Spec fingerprint: a snapshot only ever restores into an engine
        // of identical geometry.
        w.usize(spec.layers)?;
        w.usize(spec.q_heads)?;
        w.usize(spec.kv_heads)?;
        w.usize(spec.head_dim)?;
        w.usize(spec.d_model)?;
        w.usize(spec.vocab)?;
        w.str(sess.method.label())?;
        w.usize(sess.len)?;
        w.f32s(&sess.x_last)?;
        w.u64(sess.scanned_total)?;
        w.u64(sess.retrievals)?;
        w.u64(sess.drained_tokens)?;
        w.u64(sess.drains)?;
        w.bool(sess.had_removals)?;
        // v2+: the per-head policy section (assignment vector, released
        // bytes, any in-flight calibration). Streaming heads then persist
        // as two lengths in the retriever section below — their index
        // state simply does not exist to be written.
        crate::store::save_policy(&mut w, &sess.policy)?;
        w.u64(sess.index_bytes_avoided)?;
        w.bool(sess.calib.is_some())?;
        if let Some(c) = &sess.calib {
            w.usize(c.steps_done)?;
            w.usize(c.target_steps)?;
            for layer in &c.mass {
                w.f32s(layer)?;
            }
        }
        for layer in 0..spec.layers {
            for kvh in 0..spec.kv_heads {
                let cache = &sess.caches[layer][kvh];
                w.usize(cache.pattern().sink)?;
                w.usize(cache.pattern().window)?;
                w.matrix(cache.keys())?;
                w.matrix(cache.values())?;
                let (prefill_len, indexed_end, retired_end) = cache.persist_bounds();
                w.usize(prefill_len)?;
                w.usize(indexed_end)?;
                w.usize(retired_end)?;
            }
        }
        for layer in 0..spec.layers {
            for h in 0..spec.q_heads {
                w.matrix(&sess.q_history[layer][h])?;
            }
        }
        for layer in 0..spec.layers {
            for h in 0..spec.q_heads {
                w.matrix(&sess.recent_q[layer][h])?;
            }
        }
        for layer in 0..spec.layers {
            for kvh in 0..spec.kv_heads {
                crate::store::save_group(&mut w, &sess.groups[layer][kvh])?;
            }
        }
        // Heads persist structurally when every one of them can (the four
        // index families, Full, StreamingLLM); otherwise the snapshot
        // records KV + groups only and restore rebuilds the retrievers —
        // still no re-prefill, just the (cheap) fixed-set build.
        let all_saved = sess
            .retrievers
            .iter()
            .all(|layer| layer.iter().all(|r| r.supports_save()));
        w.bool(all_saved)?;
        if all_saved {
            for layer in 0..spec.layers {
                for h in 0..spec.q_heads {
                    sess.retrievers[layer][h].save_state(&mut w)?;
                }
            }
        }
        // v3: close with the checksummed footer — the payload above is
        // byte-identical to v2, so the compat writer just stops here.
        if version >= 3 {
            w.write_footer()?;
        }
        let bytes = w.bytes_written();
        let secs = t.elapsed_s();
        telemetry::span_record(&mut sess.spans, Phase::Snapshot, t.started(), secs, 0);
        telemetry::registry().histogram("store.snapshot_s").record(secs);
        Ok(bytes)
    }

    /// Rebuild a session from a snapshot stream: the exact inverse of
    /// [`Engine::snapshot_session`]. The restored session decodes its
    /// next token with zero re-prefill and zero index-rebuild work (its
    /// maintenance stats start at zero and stay there until real drains
    /// happen), and its searches are bit-identical to the source's.
    pub fn restore_session(&self, input: &mut dyn std::io::Read) -> Result<Session> {
        let t = Stopwatch::start();
        let spec = self.spec().clone();
        let mut r = crate::store::codec::SnapReader::new(input);
        let mut magic = [0u8; 4];
        r.raw(&mut magic)?;
        anyhow::ensure!(&magic == crate::store::MAGIC, "not a session snapshot");
        let version = r.u32()?;
        // Version policy: the current format plus a read path for the
        // immediately preceding one (v2 = same payload, no checksummed
        // footer); anything else is refused and the caller re-prefills.
        anyhow::ensure!(
            version == crate::store::VERSION || version == crate::store::V2,
            "snapshot format v{version} != supported v{} (version policy: refuse, re-prefill)",
            crate::store::VERSION
        );
        crate::util::failpoint::trigger("codec.restore")?;
        for (name, want) in [
            ("layers", spec.layers),
            ("q_heads", spec.q_heads),
            ("kv_heads", spec.kv_heads),
            ("head_dim", spec.head_dim),
            ("d_model", spec.d_model),
            ("vocab", spec.vocab),
        ] {
            let got = r.usize()?;
            anyhow::ensure!(got == want, "snapshot {name} {got} != engine {want}");
        }
        let method_label = r.str()?;
        let method = Method::parse(&method_label)
            .ok_or_else(|| anyhow::anyhow!("unknown method `{method_label}` in snapshot"))?;
        let len = r.usize()?;
        let x_last = r.f32s()?;
        anyhow::ensure!(x_last.len() == spec.d_model, "snapshot hidden-state width mismatch");
        let scanned_total = r.u64()?;
        let retrievals = r.u64()?;
        let drained_tokens = r.u64()?;
        let drains = r.u64()?;
        let had_removals = r.bool()?;
        // v2+ payload: the per-head policy section.
        let policy = crate::store::load_policy(&mut r, spec.layers, spec.q_heads)?;
        let index_bytes_avoided = r.u64()?;
        let calib = if r.bool()? {
            let steps_done = r.usize()?;
            let target_steps = r.usize()?;
            let mut mass = Vec::with_capacity(spec.layers);
            for _ in 0..spec.layers {
                let row = r.f32s()?;
                anyhow::ensure!(
                    row.len() == spec.q_heads,
                    "snapshot calibration row width mismatch"
                );
                mass.push(row);
            }
            Some(Calibrator { steps_done, target_steps, mass })
        } else {
            None
        };
        let mut caches: Vec<Vec<TieredKvCache>> = Vec::with_capacity(spec.layers);
        for _ in 0..spec.layers {
            let mut layer = Vec::with_capacity(spec.kv_heads);
            for _ in 0..spec.kv_heads {
                let pattern = StaticPattern { sink: r.usize()?, window: r.usize()? };
                let keys = r.matrix()?;
                let values = r.matrix()?;
                let bounds = (r.usize()?, r.usize()?, r.usize()?);
                anyhow::ensure!(keys.cols() == spec.head_dim, "snapshot KV head-dim mismatch");
                layer.push(TieredKvCache::from_parts(pattern, keys, values, bounds));
            }
            caches.push(layer);
        }
        let mut q_history: Vec<Vec<Matrix>> = Vec::with_capacity(spec.layers);
        for _ in 0..spec.layers {
            let mut layer = Vec::with_capacity(spec.q_heads);
            for _ in 0..spec.q_heads {
                layer.push(r.matrix()?);
            }
            q_history.push(layer);
        }
        let mut recent_q: Vec<Vec<Matrix>> = Vec::with_capacity(spec.layers);
        for _ in 0..spec.layers {
            let mut layer = Vec::with_capacity(spec.q_heads);
            for _ in 0..spec.q_heads {
                layer.push(r.matrix()?);
            }
            recent_q.push(layer);
        }
        let mut groups: Vec<Vec<Arc<GroupShared>>> = Vec::with_capacity(spec.layers);
        for _ in 0..spec.layers {
            let mut layer = Vec::with_capacity(spec.kv_heads);
            for _ in 0..spec.kv_heads {
                layer.push(crate::store::load_group(&mut r)?);
            }
            groups.push(layer);
        }
        let group_size = spec.group_size();
        let (retrievers, groups) = if r.bool()? {
            let mut retrievers: Vec<Vec<Arc<dyn HostRetriever>>> =
                Vec::with_capacity(spec.layers);
            for layer in 0..spec.layers {
                let mut heads: Vec<Arc<dyn HostRetriever>> = Vec::with_capacity(spec.q_heads);
                for h in 0..spec.q_heads {
                    let group = groups[layer][h / group_size].clone();
                    heads.push(Arc::from(crate::baselines::restore_retriever(&mut r, group)?));
                }
                retrievers.push(heads);
            }
            (retrievers, groups)
        } else {
            // Heads were not persisted (a non-persistable baseline is in
            // the mix): rebuild them from the restored caches/queries
            // under the restored policy. Still no re-prefill — only the
            // retriever construction.
            self.build_retrievers_with(&caches, &q_history, method, &policy)?
        };
        // v3: verify the checksummed footer before handing anything back —
        // a parse that "succeeded" over flipped bits dies here, cleanly.
        if version >= 3 {
            r.verify_footer()?;
        }
        let mut sess = Session {
            method,
            caches,
            q_history,
            retrievers,
            groups,
            maint: MaintenanceState::new(),
            recent_q,
            host_ids: Vec::new(),
            x_last,
            len,
            scanned_total,
            retrievals,
            drained_tokens,
            drains,
            had_removals,
            policy,
            calib,
            index_bytes_avoided,
            spans: SpanAcc::default(),
        };
        let secs = t.elapsed_s();
        telemetry::span_record(&mut sess.spans, Phase::Restore, t.started(), secs, 0);
        telemetry::registry().histogram("store.restore_s").record(secs);
        Ok(sess)
    }

    /// Build a session for `method` from an existing prefill state —
    /// re-runs only the retriever construction (index build), sharing the
    /// expensive prefill across methods in the accuracy experiments.
    pub fn session_for_method(&self, base: &Session, method: Method) -> Result<Session> {
        let mut sess = base.fork_state();
        // The policy is re-derived for the NEW method, not inherited: a
        // calibration verdict for RoarGraph heads says nothing about a
        // Flat comparator, and non-index-backed methods never specialize.
        let policy = self.initial_policy(method);
        let (retrievers, groups) =
            self.build_retrievers_with(&sess.caches, &sess.q_history, method, &policy)?;
        sess.method = method;
        sess.retrievers = retrievers;
        sess.groups = groups;
        sess.policy = policy;
        sess.calib = self.new_calibrator(method);
        Ok(sess)
    }

    /// Fork a live session into an independent continuation, copy-on-write
    /// (the PR-2 "cheap forks" follow-up, built on the persistence
    /// machinery's structural-sharing discipline): each GQA group is
    /// forked by sharing the segmented store's chunks and the immutable id
    /// map by `Arc` ([`GroupShared::fork`]), and each index-backed head
    /// shares the base's published front `Arc` outright — **nothing is
    /// copied at fork time**; the first maintenance op on either side
    /// clones before mutating (`IndexRetriever::apply` only ever writes to
    /// exclusively-owned buffers). The fork keeps the base's store
    /// generation, so its fronts pair with its maps exactly as the base's
    /// did. Heads that cannot fork cheaply (the fixed-set baselines with
    /// interior build state) fall back to the old full retriever rebuild.
    /// Pending maintenance on the base is flushed first so the fork can't
    /// lose in-flight drains.
    pub fn fork_session(&self, base: &mut Session) -> Result<Session> {
        base.flush_maintenance();
        let mut sess = base.fork_state();
        let spec = self.spec();
        let group_size = spec.group_size();
        let mut groups: Vec<Vec<Arc<GroupShared>>> = Vec::with_capacity(spec.layers);
        let mut retrievers: Vec<Vec<Arc<dyn HostRetriever>>> = Vec::with_capacity(spec.layers);
        let mut cow_ok = base.retrievers.len() == spec.layers && base.groups.len() == spec.layers;
        'layers: for layer in 0..spec.layers {
            if !cow_ok {
                break;
            }
            let shared: Vec<Arc<GroupShared>> =
                base.groups[layer].iter().map(|g| g.fork()).collect();
            let mut heads: Vec<Arc<dyn HostRetriever>> = Vec::with_capacity(spec.q_heads);
            for h in 0..spec.q_heads {
                match base.retrievers[layer][h].fork_with_group(shared[h / group_size].clone())
                {
                    Some(r) => heads.push(Arc::from(r)),
                    None => {
                        cow_ok = false;
                        break 'layers;
                    }
                }
            }
            groups.push(shared);
            retrievers.push(heads);
        }
        if cow_ok {
            sess.retrievers = retrievers;
            sess.groups = groups;
        } else {
            // `fork_state` copied the base's policy; the rebuild honors it
            // (streaming heads come back as window views, not indexes).
            let (retrievers, groups) = self.build_retrievers_with(
                &sess.caches,
                &sess.q_history,
                base.method,
                &sess.policy,
            )?;
            sess.retrievers = retrievers;
            sess.groups = groups;
        }
        Ok(sess)
    }

    /// Truncate a session to its first `new_len` tokens (chat rollback /
    /// regenerate-from-here). The dropped ids are tombstoned in every
    /// head's index through the deletion path when the method supports
    /// removal; otherwise the retrievers are rebuilt from the truncated
    /// caches. The caller resumes decoding by feeding the token that
    /// should now follow position `new_len - 1`.
    pub fn truncate_session(&self, sess: &mut Session, new_len: usize) -> Result<()> {
        anyhow::ensure!(new_len > 0, "cannot truncate to zero tokens");
        anyhow::ensure!(new_len <= sess.len, "truncate beyond current length");
        sess.flush_maintenance();
        let spec = self.spec();
        let group = spec.group_size();
        let removable = sess
            .retrievers
            .iter()
            .all(|layer| layer.iter().all(|r| r.supports_remove()));
        if removable && new_len < sess.len {
            // The tombstones below make this session eligible for
            // reclamation epochs (see `Session::had_removals`).
            sess.had_removals = true;
        }
        for layer in 0..spec.layers {
            for kvh in 0..spec.kv_heads {
                let old_len = sess.caches[layer][kvh].len();
                sess.caches[layer][kvh].truncate(new_len);
                // Tombstone everything from the *post-truncate* indexed
                // boundary up: that covers the dropped suffix AND any
                // surviving tokens the shorter sequence pulls back inside
                // the device window — leaving those in the index would
                // double-attend them (device + retrieval).
                let lo = sess.caches[layer][kvh].indexed_end();
                if removable && lo < old_len {
                    let dropped: Vec<u32> = (lo as u32..old_len as u32).collect();
                    // One absolute→dense resolution per group (not per head).
                    let dense = sess.groups[layer][kvh].dense_ids_for(&dropped);
                    for g in 0..group {
                        let r = &sess.retrievers[layer][kvh * group + g];
                        let ok = r.remove_dense(&dense);
                        debug_assert!(ok, "removal-capable retriever refused truncation");
                    }
                }
            }
        }
        if !removable {
            let (retrievers, groups) = self.build_retrievers_with(
                &sess.caches,
                &sess.q_history,
                sess.method,
                &sess.policy,
            )?;
            sess.retrievers = retrievers;
            sess.groups = groups;
        }
        for layer in 0..spec.layers {
            for h in 0..spec.q_heads {
                sess.q_history[layer][h].truncate_rows(new_len);
                // The recent-query ring may reflect dropped positions.
                sess.recent_q[layer][h] = Matrix::zeros(0, spec.head_dim);
            }
        }
        sess.len = new_len;
        Ok(())
    }

    /// Construct a decode-ready session directly from synthetic per-head
    /// geometry (no prefill): used by the latency experiments at context
    /// lengths where running a prompt through the model is wasteful.
    /// `heads[layer][kv_head]` provides keys/values; queries train the
    /// index for every query head of the group.
    pub fn synthetic_session(
        &self,
        heads: Vec<Vec<crate::workload::geometry::HeadGeometry>>,
        method: Method,
    ) -> Result<Session> {
        let spec = self.spec().clone();
        anyhow::ensure!(heads.len() == spec.layers, "need one geometry per layer");
        let mut caches: Vec<Vec<TieredKvCache>> = Vec::with_capacity(spec.layers);
        let mut q_history: Vec<Vec<Matrix>> = Vec::with_capacity(spec.layers);
        let mut len = 0;
        for layer_geoms in &heads {
            anyhow::ensure!(layer_geoms.len() == spec.kv_heads, "need one geometry per kv head");
            let mut layer_caches = Vec::with_capacity(spec.kv_heads);
            let mut layer_hist = Vec::with_capacity(spec.q_heads);
            for (kvh, g) in layer_geoms.iter().enumerate() {
                let mut cache = TieredKvCache::new(spec.head_dim, self.cfg.pattern);
                cache.load_prefill(g.keys.clone(), g.values.clone());
                len = cache.len();
                layer_caches.push(cache);
                // Every query head of this group trains on the same query
                // stream (per-head streams differ across kv heads only).
                for _ in 0..spec.group_size() {
                    layer_hist.push(g.queries.clone());
                }
                let _ = kvh;
            }
            caches.push(layer_caches);
            q_history.push(layer_hist);
        }
        let policy = self.initial_policy(method);
        let (retrievers, groups) =
            self.build_retrievers_with(&caches, &q_history, method, &policy)?;
        let recent_q = self.empty_recent_rings();
        Ok(Session {
            method,
            caches,
            q_history,
            retrievers,
            groups,
            maint: MaintenanceState::new(),
            recent_q,
            host_ids: Vec::new(),
            x_last: vec![0.0; self.spec().d_model],
            len,
            scanned_total: 0,
            retrievals: 0,
            drained_tokens: 0,
            drains: 0,
            had_removals: false,
            calib: self.new_calibrator(method),
            policy,
            index_bytes_avoided: 0,
            spans: SpanAcc::default(),
        })
    }
}
