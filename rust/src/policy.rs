//! The per-head attention policy layer: retrieval heads vs. streaming
//! heads (DuoAttention).
//!
//! DuoAttention's observation is that only a fraction of attention heads
//! are true *retrieval* heads — heads whose output degrades when distant
//! tokens are dropped. The rest are *streaming* heads: they attend almost
//! exclusively to the attention sinks plus a recent window, and need no
//! long-context ANN index at all. This module holds the policy model:
//!
//! * [`HeadPolicy`] — what one query head gets: the full indexed
//!   retrieval tier, or a constant-length sink+window set.
//! * [`HeadPolicyConfig`] — the `retrieval.policy` config block: the
//!   assignment mode, the calibration knobs, and static override lists.
//! * [`PolicyMap`] — the per-(layer, query-head) assignment carried by a
//!   session (and persisted in RASS v2 snapshots).
//! * [`Calibrator`] — the training-free online profiling pass: the decode
//!   path already computes, per head, the softmax partition between the
//!   device static set (exactly the sink+window span) and the retrieved
//!   host set — so the fraction of attention mass a head places on the
//!   span is `exp(lse_dev − lse_merged)`, free of any extra compute.
//!   Heads whose mean span-mass over `calibration_steps` decode steps
//!   meets `mass_threshold` are flipped to streaming.
//!
//! The policy only changes behaviour for the index-backed methods
//! (Flat / IVF / HNSW / RetrievalAttention): the fixed-set baselines
//! already embody a per-method policy of their own. With `mode = off`
//! (the default) every code path is byte-for-byte the pre-policy one.

use crate::util::json::Value;

/// What one query head's host-side retrieval tier looks like.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadPolicy {
    /// Full indexed tier: ANN search over the host keys every step.
    Retrieval,
    /// Constant-length tier: the first `sinks` and last `window` host
    /// tokens of the head's GQA group, no index, no search.
    Streaming { sinks: usize, window: usize },
}

impl HeadPolicy {
    pub fn is_streaming(&self) -> bool {
        matches!(self, HeadPolicy::Streaming { .. })
    }
}

/// How head policies are assigned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyMode {
    /// Policy layer disabled: every head is a retrieval head and every
    /// code path is the pre-policy one (the default).
    Off,
    /// Assignment comes purely from the config's override lists at
    /// session-build time; no profiling pass runs.
    Static,
    /// Online calibration: profile `calibration_steps` decode steps,
    /// then flip heads whose sink+window attention mass meets
    /// `mass_threshold` (override lists still apply on top).
    Calibrated,
}

impl PolicyMode {
    pub fn label(&self) -> &'static str {
        match self {
            PolicyMode::Off => "off",
            PolicyMode::Static => "static",
            PolicyMode::Calibrated => "calibrated",
        }
    }

    pub fn parse(s: &str) -> Option<PolicyMode> {
        [PolicyMode::Off, PolicyMode::Static, PolicyMode::Calibrated]
            .into_iter()
            .find(|m| m.label().eq_ignore_ascii_case(s))
    }
}

/// The `retrieval.policy` config block.
#[derive(Clone, Debug, PartialEq)]
pub struct HeadPolicyConfig {
    pub mode: PolicyMode,
    /// Profiling decode steps before the calibrated assignment is applied.
    pub calibration_steps: usize,
    /// Mean sink+window attention-mass fraction at or above which a head
    /// is flipped to streaming (DuoAttention's retrieval heads sit far
    /// below this; its streaming heads sit essentially at 1.0).
    pub mass_threshold: f32,
    /// Host-side sink tokens a streaming head keeps reading.
    pub sinks: usize,
    /// Host-side recent-window tokens a streaming head keeps reading.
    pub window: usize,
    /// `(layer, query_head)` pairs forced to streaming regardless of the
    /// calibration outcome (or, in `static` mode, the whole assignment).
    pub force_streaming: Vec<(usize, usize)>,
    /// `(layer, query_head)` pairs pinned to retrieval no matter what the
    /// profiling says. Wins over `force_streaming` on conflict: pinning a
    /// head to the exact tier is the safe direction.
    pub force_retrieval: Vec<(usize, usize)>,
}

impl Default for HeadPolicyConfig {
    fn default() -> Self {
        HeadPolicyConfig {
            mode: PolicyMode::Off,
            calibration_steps: 16,
            mass_threshold: 0.98,
            sinks: 128,
            window: 1024,
            force_streaming: Vec::new(),
            force_retrieval: Vec::new(),
        }
    }
}

fn pairs_to_json(pairs: &[(usize, usize)]) -> Value {
    Value::Arr(
        pairs
            .iter()
            .map(|&(l, h)| Value::Arr(vec![Value::from(l), Value::from(h)]))
            .collect(),
    )
}

fn pairs_from_json(v: &Value, field: &str) -> anyhow::Result<Vec<(usize, usize)>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("policy.{field} must be an array of [layer, head]"))?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        let pair = item.as_arr().filter(|p| p.len() == 2).ok_or_else(|| {
            anyhow::anyhow!("policy.{field} entries must be [layer, head] pairs")
        })?;
        match (pair[0].as_usize(), pair[1].as_usize()) {
            (Some(l), Some(h)) => out.push((l, h)),
            _ => anyhow::bail!("policy.{field} entries must be numeric [layer, head] pairs"),
        }
    }
    Ok(out)
}

impl HeadPolicyConfig {
    /// Whether the policy layer does anything at all.
    pub fn enabled(&self) -> bool {
        self.mode != PolicyMode::Off
    }

    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("mode", self.mode.label())
            .set("calibration_steps", self.calibration_steps)
            .set("mass_threshold", self.mass_threshold as f64)
            .set("sinks", self.sinks)
            .set("window", self.window)
            .set("force_streaming", pairs_to_json(&self.force_streaming))
            .set("force_retrieval", pairs_to_json(&self.force_retrieval));
        o
    }

    /// Overlay fields present in `v` onto `self` (the config system's
    /// absent-fields-keep-defaults discipline).
    pub fn apply_json(&mut self, v: &Value) -> anyhow::Result<()> {
        if let Some(m) = v.get("mode").and_then(Value::as_str) {
            self.mode = PolicyMode::parse(m)
                .ok_or_else(|| anyhow::anyhow!("unknown policy mode `{m}`"))?;
        }
        if let Some(x) = v.get("calibration_steps").and_then(Value::as_usize) {
            self.calibration_steps = x;
        }
        if let Some(x) = v.get("mass_threshold").and_then(Value::as_f64) {
            self.mass_threshold = x as f32;
        }
        if let Some(x) = v.get("sinks").and_then(Value::as_usize) {
            self.sinks = x;
        }
        if let Some(x) = v.get("window").and_then(Value::as_usize) {
            self.window = x;
        }
        if let Some(x) = v.get("force_streaming") {
            self.force_streaming = pairs_from_json(x, "force_streaming")?;
        }
        if let Some(x) = v.get("force_retrieval") {
            self.force_retrieval = pairs_from_json(x, "force_retrieval")?;
        }
        Ok(())
    }

    /// The assignment available without profiling: every head retrieval,
    /// minus the override lists. This is the whole policy in `static`
    /// mode, and the session-build starting point in `calibrated` mode
    /// (heads flip only after the profiling pass completes).
    pub fn static_map(&self, layers: usize, q_heads: usize) -> PolicyMap {
        let mut map = PolicyMap::all_retrieval(layers, q_heads);
        if self.mode == PolicyMode::Off {
            return map;
        }
        for &(l, h) in &self.force_streaming {
            map.set(l, h, HeadPolicy::Streaming { sinks: self.sinks, window: self.window });
        }
        for &(l, h) in &self.force_retrieval {
            map.set(l, h, HeadPolicy::Retrieval);
        }
        map
    }
}

/// The per-(layer, query-head) policy assignment a session carries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PolicyMap {
    /// `heads[layer][q_head]`.
    pub heads: Vec<Vec<HeadPolicy>>,
}

impl PolicyMap {
    /// The identity assignment: every head keeps the full indexed tier.
    pub fn all_retrieval(layers: usize, q_heads: usize) -> PolicyMap {
        PolicyMap { heads: vec![vec![HeadPolicy::Retrieval; q_heads]; layers] }
    }

    /// Policy of one head; out-of-range coordinates (an override list
    /// naming a head the model doesn't have) read as `Retrieval`.
    pub fn get(&self, layer: usize, head: usize) -> HeadPolicy {
        self.heads
            .get(layer)
            .and_then(|l| l.get(head))
            .copied()
            .unwrap_or(HeadPolicy::Retrieval)
    }

    /// Set one head's policy; out-of-range coordinates are ignored.
    pub fn set(&mut self, layer: usize, head: usize, p: HeadPolicy) {
        if let Some(slot) = self.heads.get_mut(layer).and_then(|l| l.get_mut(head)) {
            *slot = p;
        }
    }

    pub fn num_streaming(&self) -> usize {
        self.heads.iter().flatten().filter(|p| p.is_streaming()).count()
    }

    pub fn num_heads(&self) -> usize {
        self.heads.iter().map(Vec::len).sum()
    }

    /// Fraction of heads assigned the streaming tier (the done-event /
    /// bench metric; 0.0 for an empty or all-retrieval map).
    pub fn streaming_fraction(&self) -> f64 {
        let total = self.num_heads();
        if total == 0 {
            0.0
        } else {
            self.num_streaming() as f64 / total as f64
        }
    }
}

/// The online profiling pass: accumulates, per head, the fraction of
/// attention mass the decode step placed on the device static set (the
/// sink+window span). The signal is free — the engine already holds both
/// partials' log-sum-exps when it γ-combines them.
#[derive(Clone, Debug)]
pub struct Calibrator {
    /// Completed profiling decode steps.
    pub steps_done: usize,
    /// Profiling steps required before the assignment is applied.
    pub target_steps: usize,
    /// Accumulated span-mass fraction per `[layer][q_head]` (f32 so the
    /// snapshot round-trip is exact).
    pub mass: Vec<Vec<f32>>,
}

impl Calibrator {
    pub fn new(layers: usize, q_heads: usize, target_steps: usize) -> Calibrator {
        Calibrator {
            steps_done: 0,
            target_steps,
            mass: vec![vec![0.0; q_heads]; layers],
        }
    }

    /// Accumulate one head's span-mass fraction for the current step.
    pub fn record(&mut self, layer: usize, head: usize, frac: f32) {
        if let Some(slot) = self.mass.get_mut(layer).and_then(|l| l.get_mut(head)) {
            *slot += frac;
        }
    }

    /// Numerically stable span-mass fraction from the two partials' LSEs:
    /// `exp(lse_span) / (exp(lse_span) + exp(lse_rest))`. A head with no
    /// host-side partial (`lse_rest = -inf`) has all its mass on the span.
    pub fn span_mass(lse_span: f32, lse_rest: f32) -> f32 {
        if !lse_rest.is_finite() {
            return 1.0;
        }
        if !lse_span.is_finite() {
            return 0.0;
        }
        let m = lse_span.max(lse_rest);
        let a = (lse_span - m).exp();
        let b = (lse_rest - m).exp();
        a / (a + b)
    }

    /// Mark one decode step complete; returns `true` once the profiling
    /// budget is spent and the assignment should be decided.
    pub fn end_step(&mut self) -> bool {
        self.steps_done += 1;
        self.steps_done >= self.target_steps
    }

    /// Decide the assignment: mean span mass ≥ threshold ⇒ streaming,
    /// then the config's override lists on top (retrieval pin wins).
    pub fn decide(&self, cfg: &HeadPolicyConfig) -> PolicyMap {
        let layers = self.mass.len();
        let q_heads = self.mass.first().map(Vec::len).unwrap_or(0);
        let mut map = PolicyMap::all_retrieval(layers, q_heads);
        if self.steps_done > 0 {
            for (l, layer) in self.mass.iter().enumerate() {
                for (h, &acc) in layer.iter().enumerate() {
                    let mean = acc / self.steps_done as f32;
                    if mean >= cfg.mass_threshold {
                        map.set(
                            l,
                            h,
                            HeadPolicy::Streaming { sinks: cfg.sinks, window: cfg.window },
                        );
                    }
                }
            }
        }
        for &(l, h) in &cfg.force_streaming {
            map.set(l, h, HeadPolicy::Streaming { sinks: cfg.sinks, window: cfg.window });
        }
        for &(l, h) in &cfg.force_retrieval {
            map.set(l, h, HeadPolicy::Retrieval);
        }
        // Policy-family telemetry: one calibration verdict committed.
        // (The per-session gauges — streaming fraction, released index
        // bytes — are set where the verdict is *applied*, since only the
        // session knows how many bytes its indexes actually held.)
        crate::telemetry::registry().counter("policy.calibrations_total").inc();
        map
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_off_and_roundtrips() {
        let cfg = HeadPolicyConfig::default();
        assert!(!cfg.enabled());
        let mut back = HeadPolicyConfig::default();
        back.apply_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // Off-mode static map is the identity assignment even with
        // overrides present (the layer is disabled).
        let mut off = cfg.clone();
        off.force_streaming = vec![(0, 1)];
        assert_eq!(off.static_map(2, 4).num_streaming(), 0);
    }

    #[test]
    fn config_roundtrips_with_overrides() {
        let cfg = HeadPolicyConfig {
            mode: PolicyMode::Calibrated,
            calibration_steps: 4,
            mass_threshold: 0.5,
            sinks: 8,
            window: 64,
            force_streaming: vec![(0, 2), (1, 3)],
            force_retrieval: vec![(0, 0)],
        };
        let mut back = HeadPolicyConfig::default();
        back.apply_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        assert!(PolicyMode::parse("nope").is_none());
    }

    #[test]
    fn static_map_applies_overrides_with_retrieval_precedence() {
        let cfg = HeadPolicyConfig {
            mode: PolicyMode::Static,
            force_streaming: vec![(0, 1), (1, 0), (1, 0)],
            force_retrieval: vec![(1, 0)],
            ..HeadPolicyConfig::default()
        };
        let map = cfg.static_map(2, 2);
        assert!(map.get(0, 1).is_streaming());
        assert_eq!(map.get(1, 0), HeadPolicy::Retrieval, "retrieval pin wins");
        assert_eq!(map.num_streaming(), 1);
        assert!((map.streaming_fraction() - 0.25).abs() < 1e-12);
        // Out-of-range overrides are ignored, and reads past the model
        // geometry come back Retrieval.
        let cfg2 = HeadPolicyConfig {
            mode: PolicyMode::Static,
            force_streaming: vec![(9, 9)],
            ..HeadPolicyConfig::default()
        };
        assert_eq!(cfg2.static_map(2, 2).num_streaming(), 0);
        assert_eq!(map.get(9, 9), HeadPolicy::Retrieval);
    }

    #[test]
    fn span_mass_is_stable_and_bounded() {
        assert_eq!(Calibrator::span_mass(0.0, f32::NEG_INFINITY), 1.0);
        assert_eq!(Calibrator::span_mass(f32::NEG_INFINITY, 0.0), 0.0);
        let half = Calibrator::span_mass(3.0, 3.0);
        assert!((half - 0.5).abs() < 1e-6);
        // Huge magnitudes don't overflow.
        let big = Calibrator::span_mass(500.0, 490.0);
        assert!(big > 0.99 && big <= 1.0);
        let small = Calibrator::span_mass(-500.0, -490.0);
        assert!(small < 0.01 && small >= 0.0);
    }

    #[test]
    fn calibrator_flips_high_mass_heads_and_respects_overrides() {
        let cfg = HeadPolicyConfig {
            mode: PolicyMode::Calibrated,
            calibration_steps: 2,
            mass_threshold: 0.9,
            sinks: 4,
            window: 16,
            force_streaming: vec![(0, 3)],
            force_retrieval: vec![(0, 1)],
            ..HeadPolicyConfig::default()
        };
        let mut cal = Calibrator::new(1, 4, cfg.calibration_steps);
        for _ in 0..2 {
            cal.record(0, 0, 0.99); // streaming by mass
            cal.record(0, 1, 0.99); // ...but pinned retrieval
            cal.record(0, 2, 0.10); // retrieval by mass
            cal.record(0, 3, 0.10); // ...but forced streaming
        }
        assert!(!cal.end_step());
        assert!(cal.end_step());
        let map = cal.decide(&cfg);
        assert_eq!(map.get(0, 0), HeadPolicy::Streaming { sinks: 4, window: 16 });
        assert_eq!(map.get(0, 1), HeadPolicy::Retrieval);
        assert_eq!(map.get(0, 2), HeadPolicy::Retrieval);
        assert!(map.get(0, 3).is_streaming());
        assert_eq!(map.num_streaming(), 2);
        assert_eq!(map.num_heads(), 4);
    }
}
