//! Host-data stub of the `xla-rs` PJRT surface.
//!
//! The real crate links `xla_extension` (the XLA C++ runtime) and executes
//! AOT-lowered HLO on a PJRT device. This vendored stand-in keeps the exact
//! API shape the runtime layer compiles against, but holds every tensor as
//! host memory and refuses to *execute* HLO — `PjRtClient::compile` returns
//! an error, which the runtime layer treats as "PJRT unavailable" and falls
//! back to its native Rust executor (`runtime::native`). Buffers and
//! literals are fully functional, so the native executor can read argument
//! data straight out of [`PjRtBuffer`]s.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` far enough for `{e}` formatting.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types carried by [`Literal`]s and [`PjRtBuffer`]s.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Data
    where
        Self: Sized;
    fn unwrap(data: &Data) -> Option<&[Self]>
    where
        Self: Sized;
}

/// Tensor payload.
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Option<&[f32]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }
    fn unwrap(data: &Data) -> Option<&[i32]> {
        match data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// A host tensor (mirrors `xla::Literal`).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<usize>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len()], data: T::wrap(data.to_vec()) }
    }

    /// Build with an explicit shape (stub extension used by the native
    /// executor to construct outputs).
    pub fn from_f32(data: Vec<f32>, dims: &[usize]) -> Literal {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Literal { data: Data::F32(data), dims: dims.to_vec() }
    }

    /// Tuple literal (stub extension).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { data: Data::Tuple(parts), dims: Vec::new() }
    }

    pub fn reshape(mut self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let len = self.element_count();
        if n as usize != len {
            return Err(Error(format!("reshape {dims:?} does not match {len} elements")));
        }
        self.dims = dims.iter().map(|&d| d as usize).collect();
        Ok(self)
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.len(),
        }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Borrow the payload as `f32` (stub extension; avoids a copy in the
    /// native executor).
    pub fn f32s(&self) -> Option<&[f32]> {
        f32::unwrap(&self.data)
    }

    /// Borrow the payload as `i32` (stub extension).
    pub fn i32s(&self) -> Option<&[i32]> {
        i32::unwrap(&self.data)
    }

    /// Flatten a tuple literal into its parts. Non-tuples behave as 1-ary
    /// tuples (the AOT pipeline always lowers with `return_tuple=True`).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(parts) => Ok(parts),
            _ => Ok(vec![self]),
        }
    }
}

/// A "device" buffer: in the stub, host memory with a shape.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// The underlying host literal (stub extension for the native executor).
    pub fn literal(&self) -> &Literal {
        &self.literal
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Parsed HLO module (the stub only retains the source text).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("reading {}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// PJRT client. The stub can create buffers but cannot compile HLO.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(
            "PJRT unavailable: vendored xla stub cannot execute HLO (native runtime backend \
             will be used instead)"
                .into(),
        ))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(Error(format!("shape {dims:?} does not match {} elements", data.len())));
        }
        let mut lit = Literal::vec1(data);
        lit.dims = dims.to_vec();
        Ok(PjRtBuffer { literal: lit })
    }
}

/// Compiled executable handle. Never constructible through the stub's
/// `compile`, so `execute` is unreachable in practice; it still returns a
/// well-formed error to keep call sites honest.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[&Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error("stub xla cannot execute HLO".into()))
    }

    pub fn execute_b<T>(&self, _inputs: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error("stub xla cannot execute HLO".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_rejects_bad_shape() {
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn buffer_carries_host_data() {
        let c = PjRtClient::cpu().unwrap();
        let b = c.buffer_from_host_buffer(&[1i32, 2, 3], &[3], None).unwrap();
        assert_eq!(b.literal().i32s().unwrap(), &[1, 2, 3]);
        assert_eq!(b.to_literal_sync().unwrap().to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn tuple_flattening() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2.0f32])]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
        let single = Literal::vec1(&[5.0f32]);
        assert_eq!(single.to_tuple().unwrap().len(), 1);
    }

    #[test]
    fn compile_is_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto { text: "HloModule x".into() });
        assert!(c.compile(&comp).is_err());
    }
}
