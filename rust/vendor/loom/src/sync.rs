//! Modeled `std::sync` twins. Each operation is a scheduling point when
//! the calling thread belongs to an active model; otherwise it delegates
//! directly to `std`. Lock blocking is modeled as try-acquire +
//! park-until-release, so the scheduler (not the OS) decides who wins a
//! contended lock in every explored order.
//!
//! `Arc` is re-exported from `std` unchanged: the checker explores
//! interleavings, not reference-count leaks.

pub use std::sync::Arc;

use crate::sched;
use std::ops::{Deref, DerefMut};
use std::sync::{LockResult, PoisonError, TryLockError};

fn point() {
    if let Some((sched, tid)) = sched::current() {
        sched.yield_point(tid);
    }
}

/// Park the current modeled thread until `rid` is released. Only called
/// when `sched::current()` is Some (a failed try-acquire implies a
/// modeled contender holds the lock; unmodeled threads use OS blocking).
fn block_on(rid: usize) {
    if let Some((sched, tid)) = sched::current() {
        sched.block_on(tid, rid);
    } else {
        std::thread::yield_now();
    }
}

fn release(rid: usize) {
    if let Some((sched, tid)) = sched::current() {
        sched.release(tid, rid);
    }
}

// ---------------------------------------------------------------- Mutex

pub struct Mutex<T> {
    inner: std::sync::Mutex<T>,
}

pub struct MutexGuard<'a, T> {
    inner: Option<std::sync::MutexGuard<'a, T>>,
    rid: usize,
}

impl<T> Mutex<T> {
    pub const fn new(t: T) -> Mutex<T> {
        Mutex { inner: std::sync::Mutex::new(t) }
    }

    fn rid(&self) -> usize {
        self as *const Mutex<T> as usize
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if sched::current().is_none() {
            return match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { inner: Some(g), rid: 0 }),
                Err(p) => {
                    Err(PoisonError::new(MutexGuard { inner: Some(p.into_inner()), rid: 0 }))
                }
            };
        }
        let rid = self.rid();
        point();
        loop {
            match self.inner.try_lock() {
                Ok(g) => return Ok(MutexGuard { inner: Some(g), rid }),
                Err(TryLockError::Poisoned(p)) => {
                    return Err(PoisonError::new(MutexGuard { inner: Some(p.into_inner()), rid }))
                }
                Err(TryLockError::WouldBlock) => block_on(rid),
            }
        }
    }
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard taken")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard taken")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Release the OS lock first, then tell the scheduler: woken
        // waiters re-try-acquire, so the order matters.
        drop(self.inner.take());
        if self.rid != 0 {
            release(self.rid);
        }
    }
}

// --------------------------------------------------------------- RwLock

pub struct RwLock<T> {
    inner: std::sync::RwLock<T>,
}

pub struct RwLockReadGuard<'a, T> {
    inner: Option<std::sync::RwLockReadGuard<'a, T>>,
    rid: usize,
}

pub struct RwLockWriteGuard<'a, T> {
    inner: Option<std::sync::RwLockWriteGuard<'a, T>>,
    rid: usize,
}

impl<T> RwLock<T> {
    pub const fn new(t: T) -> RwLock<T> {
        RwLock { inner: std::sync::RwLock::new(t) }
    }

    fn rid(&self) -> usize {
        self as *const RwLock<T> as usize
    }

    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        if sched::current().is_none() {
            return match self.inner.read() {
                Ok(g) => Ok(RwLockReadGuard { inner: Some(g), rid: 0 }),
                Err(p) => Err(PoisonError::new(RwLockReadGuard {
                    inner: Some(p.into_inner()),
                    rid: 0,
                })),
            };
        }
        let rid = self.rid();
        point();
        loop {
            match self.inner.try_read() {
                Ok(g) => return Ok(RwLockReadGuard { inner: Some(g), rid }),
                Err(TryLockError::Poisoned(p)) => {
                    return Err(PoisonError::new(RwLockReadGuard {
                        inner: Some(p.into_inner()),
                        rid,
                    }))
                }
                Err(TryLockError::WouldBlock) => block_on(rid),
            }
        }
    }

    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        if sched::current().is_none() {
            return match self.inner.write() {
                Ok(g) => Ok(RwLockWriteGuard { inner: Some(g), rid: 0 }),
                Err(p) => Err(PoisonError::new(RwLockWriteGuard {
                    inner: Some(p.into_inner()),
                    rid: 0,
                })),
            };
        }
        let rid = self.rid();
        point();
        loop {
            match self.inner.try_write() {
                Ok(g) => return Ok(RwLockWriteGuard { inner: Some(g), rid }),
                Err(TryLockError::Poisoned(p)) => {
                    return Err(PoisonError::new(RwLockWriteGuard {
                        inner: Some(p.into_inner()),
                        rid,
                    }))
                }
                Err(TryLockError::WouldBlock) => block_on(rid),
            }
        }
    }
}

impl<T> Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard taken")
    }
}

impl<T> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        drop(self.inner.take());
        if self.rid != 0 {
            release(self.rid);
        }
    }
}

impl<T> Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard taken")
    }
}

impl<T> DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard taken")
    }
}

impl<T> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        drop(self.inner.take());
        if self.rid != 0 {
            release(self.rid);
        }
    }
}

// -------------------------------------------------------------- atomics

pub mod atomic {
    pub use std::sync::atomic::Ordering;

    use super::point;
    use std::sync::atomic::Ordering::SeqCst;

    /// The model explores interleavings under sequential consistency,
    /// so every modeled access runs SeqCst; outside a model the caller's
    /// ordering is passed straight through.
    macro_rules! atomic_common {
        ($name:ident, $std:ty, $prim:ty) => {
            pub struct $name {
                inner: $std,
            }

            impl $name {
                pub const fn new(v: $prim) -> $name {
                    $name { inner: <$std>::new(v) }
                }

                pub fn load(&self, order: Ordering) -> $prim {
                    if crate::sched::current().is_some() {
                        point();
                        self.inner.load(SeqCst)
                    } else {
                        self.inner.load(order)
                    }
                }

                pub fn store(&self, v: $prim, order: Ordering) {
                    if crate::sched::current().is_some() {
                        point();
                        self.inner.store(v, SeqCst)
                    } else {
                        self.inner.store(v, order)
                    }
                }

                pub fn swap(&self, v: $prim, order: Ordering) -> $prim {
                    if crate::sched::current().is_some() {
                        point();
                        self.inner.swap(v, SeqCst)
                    } else {
                        self.inner.swap(v, order)
                    }
                }

                pub fn compare_exchange(
                    &self,
                    current: $prim,
                    new: $prim,
                    success: Ordering,
                    failure: Ordering,
                ) -> Result<$prim, $prim> {
                    if crate::sched::current().is_some() {
                        point();
                        self.inner.compare_exchange(current, new, SeqCst, SeqCst)
                    } else {
                        self.inner.compare_exchange(current, new, success, failure)
                    }
                }
            }
        };
    }

    macro_rules! atomic_numeric {
        ($name:ident, $std:ty, $prim:ty) => {
            atomic_common!($name, $std, $prim);

            impl $name {
                pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                    if crate::sched::current().is_some() {
                        point();
                        self.inner.fetch_add(v, SeqCst)
                    } else {
                        self.inner.fetch_add(v, order)
                    }
                }

                pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                    if crate::sched::current().is_some() {
                        point();
                        self.inner.fetch_sub(v, SeqCst)
                    } else {
                        self.inner.fetch_sub(v, order)
                    }
                }
            }
        };
    }

    atomic_common!(AtomicBool, std::sync::atomic::AtomicBool, bool);
    atomic_numeric!(AtomicU32, std::sync::atomic::AtomicU32, u32);
    atomic_numeric!(AtomicU64, std::sync::atomic::AtomicU64, u64);
    atomic_numeric!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
}
