//! Modeled threads: `loom::thread::spawn` registers the thread with the
//! active scheduler so every one of its sync ops becomes a scheduling
//! point. Outside a model it is a transparent `std::thread` wrapper.

use crate::sched::{self, Sched};
use std::sync::{Arc, Mutex};

pub struct JoinHandle<T> {
    inner: Inner<T>,
}

enum Inner<T> {
    Std(std::thread::JoinHandle<T>),
    Model { tid: usize, slot: Arc<Mutex<Option<T>>>, sched: Arc<Sched> },
}

pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    match sched::current() {
        Some((sched, _)) => {
            let slot: Arc<Mutex<Option<T>>> = Arc::new(Mutex::new(None));
            let tid = sched::spawn_modeled(&sched, f, Arc::clone(&slot));
            JoinHandle { inner: Inner::Model { tid, slot, sched } }
        }
        None => JoinHandle { inner: Inner::Std(std::thread::spawn(f)) },
    }
}

impl<T> JoinHandle<T> {
    pub fn join(self) -> std::thread::Result<T> {
        match self.inner {
            Inner::Std(h) => h.join(),
            Inner::Model { tid, slot, sched } => {
                let (_, cur) = sched::current()
                    .expect("loom: JoinHandle::join called off a modeled thread");
                sched.join_wait(cur, tid);
                match slot.lock().unwrap_or_else(|e| e.into_inner()).take() {
                    Some(v) => Ok(v),
                    // The target panicked; the model as a whole is already
                    // failing, surface a join error like std would.
                    None => Err(Box::new("loom: joined thread panicked")),
                }
            }
        }
    }
}

/// In a model: a *voluntary* scheduling point that always hands the
/// token to another runnable thread (never counted as a preemption), so
/// spin-retry loops let their writer make progress. Outside a model:
/// `std::thread::yield_now`.
pub fn yield_now() {
    match sched::current() {
        Some((sched, tid)) => sched.yield_voluntary(tid),
        None => std::thread::yield_now(),
    }
}
