//! The token-passing exploration scheduler.
//!
//! Exactly one modeled thread holds the execution token at any moment;
//! everyone else parks on the shared condvar. A thread gives the token
//! up at *scheduling points* (atomic ops, lock ops, yields, blocking,
//! finishing), where `pick_next` consults the DFS explorer: replay the
//! recorded prefix first, then always take the first candidate, and
//! record every branch point so `next_prefix` can flip the deepest
//! untried alternative for the following execution.

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Status {
    Runnable,
    /// Waiting for `release(rid)`; rids are lock addresses or
    /// join tokens, opaque and unique within one execution.
    Blocked(usize),
    Finished,
}

/// One recorded branch point: the runnable candidates (in exploration
/// order) and which index was taken this execution.
struct Decision {
    candidates: Vec<usize>,
    chosen: usize,
}

struct State {
    status: Vec<Status>,
    /// Thread currently holding the execution token.
    active: usize,
    /// Index of the next *branch* decision (points with >1 candidate).
    decision: usize,
    /// Replay prefix: the tid to take at each of the first
    /// `prefix.len()` branch decisions.
    prefix: Vec<usize>,
    trace: Vec<Decision>,
    preemptions: usize,
    /// Scheduling points passed this execution; a runaway count means a
    /// livelock (spin loop with no modeled yield) and fails the model
    /// loudly instead of hanging the test under its `timeout` wrapper.
    steps: usize,
    failed: Option<String>,
}

pub(crate) struct Sched {
    state: Mutex<State>,
    cv: Condvar,
    bound: usize,
    os_handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

thread_local! {
    /// Set on modeled threads only; unregistered threads (e.g. a
    /// `std::thread::scope` fan-out inside modeled code) fall through to
    /// plain std behavior at every primitive.
    static CURRENT: RefCell<Option<(Arc<Sched>, usize)>> = const { RefCell::new(None) };
}

pub(crate) fn current() -> Option<(Arc<Sched>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Join-wait token for thread `tid`: disjoint from heap addresses.
fn join_rid(tid: usize) -> usize {
    usize::MAX - tid
}

fn payload_str(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

impl Sched {
    pub(crate) fn new(prefix: Vec<usize>, bound: usize) -> Arc<Sched> {
        Arc::new(Sched {
            state: Mutex::new(State {
                status: Vec::new(),
                active: 0,
                decision: 0,
                prefix,
                trace: Vec::new(),
                preemptions: 0,
                steps: 0,
                failed: None,
            }),
            cv: Condvar::new(),
            bound,
            os_handles: Mutex::new(Vec::new()),
        })
    }

    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Choose who runs next; returns `None` when every thread finished
    /// (or on deadlock, which sets `failed`). `voluntary` marks switches
    /// that must not count against the preemption bound (blocking,
    /// `yield_now`).
    fn pick_next(&self, st: &mut State, cur: usize, voluntary: bool) -> Option<usize> {
        let runnable: Vec<usize> = st
            .status
            .iter()
            .enumerate()
            .filter(|(_, s)| matches!(s, Status::Runnable))
            .map(|(i, _)| i)
            .collect();
        if runnable.is_empty() {
            if st.status.iter().all(|s| matches!(s, Status::Finished)) {
                return None;
            }
            if st.failed.is_none() {
                st.failed = Some(format!(
                    "deadlock: no runnable thread (status: {:?})",
                    st.status
                ));
            }
            return None;
        }
        let cur_runnable = matches!(st.status.get(cur), Some(Status::Runnable));
        let candidates: Vec<usize> = if !cur_runnable {
            runnable
        } else if voluntary {
            // Voluntary yield: hand the token on. "Stay" is deliberately
            // NOT an alternative — a yield in a spin loop would otherwise
            // give the DFS an infinite spin-forever branch. This assumes
            // yield loops are side-effect free between yields (standard
            // loom guidance), so re-running the spin body without any
            // other thread progressing cannot change the outcome.
            let others: Vec<usize> = runnable.iter().copied().filter(|&t| t != cur).collect();
            if others.is_empty() {
                vec![cur]
            } else {
                others
            }
        } else if st.preemptions >= self.bound {
            vec![cur]
        } else {
            let mut c = vec![cur];
            c.extend(runnable.iter().copied().filter(|&t| t != cur));
            c
        };
        let chosen = if candidates.len() == 1 {
            candidates[0]
        } else {
            let pick = if st.decision < st.prefix.len() {
                let want = st.prefix[st.decision];
                if !candidates.contains(&want) {
                    // A model must be schedule-deterministic; divergence
                    // here means it branched on time, RNG, or an
                    // unregistered thread.
                    if st.failed.is_none() {
                        st.failed = Some(format!(
                            "schedule replay diverged: wanted tid {want}, \
                             candidates {candidates:?} (model is nondeterministic)"
                        ));
                    }
                    candidates[0]
                } else {
                    want
                }
            } else {
                candidates[0]
            };
            let idx = candidates.iter().position(|&t| t == pick).unwrap_or(0);
            st.trace.push(Decision { candidates: candidates.clone(), chosen: idx });
            st.decision += 1;
            pick
        };
        if !voluntary && cur_runnable && chosen != cur {
            st.preemptions += 1;
        }
        st.active = chosen;
        Some(chosen)
    }

    /// Give up the token at a scheduling point. `block_on: Some(rid)`
    /// parks the thread until `release(rid)`. `quiet` suppresses the
    /// propagation panic (for calls made while already unwinding).
    fn switch(&self, tid: usize, block_on: Option<usize>, voluntary: bool, quiet: bool) {
        let mut st = self.lock();
        if st.failed.is_some() {
            drop(st);
            if quiet {
                return;
            }
            panic!("loom: model failed in another thread");
        }
        st.steps += 1;
        if st.steps > 1_000_000 {
            st.failed = Some(
                "livelock suspected: one execution passed 1e6 scheduling points \
                 (a spin loop without a modeled yield?)"
                    .to_string(),
            );
            self.cv.notify_all();
            drop(st);
            if quiet {
                return;
            }
            panic!("loom: model failed in another thread");
        }
        if let Some(rid) = block_on {
            st.status[tid] = Status::Blocked(rid);
        }
        match self.pick_next(&mut st, tid, voluntary || block_on.is_some()) {
            Some(next) if next == tid => {}
            _ => {
                // Either another thread was chosen, or pick_next hit a
                // deadlock (failed set, everyone gets woken to unwind).
                self.cv.notify_all();
                loop {
                    if st.failed.is_some() {
                        drop(st);
                        if quiet {
                            return;
                        }
                        panic!("loom: model failed in another thread");
                    }
                    if st.active == tid && st.status[tid] == Status::Runnable {
                        break;
                    }
                    st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
                }
            }
        }
    }

    /// A plain scheduling point (before an atomic op / lock attempt).
    pub(crate) fn yield_point(&self, tid: usize) {
        self.switch(tid, None, false, std::thread::panicking());
    }

    /// A voluntary yield (`thread::yield_now` in a spin loop).
    pub(crate) fn yield_voluntary(&self, tid: usize) {
        self.switch(tid, None, true, std::thread::panicking());
    }

    /// Park until `rid` is released. Token-passing makes the caller's
    /// preceding try-acquire + this block atomic: no other modeled
    /// thread can run (and release the lock) in between.
    pub(crate) fn block_on(&self, tid: usize, rid: usize) {
        self.switch(tid, Some(rid), true, std::thread::panicking());
    }

    /// Wake every thread parked on `rid` and pass through a scheduling
    /// point. Called from guard drops, so it must never panic.
    pub(crate) fn release(&self, tid: usize, rid: usize) {
        {
            let mut st = self.lock();
            for s in st.status.iter_mut() {
                if *s == Status::Blocked(rid) {
                    *s = Status::Runnable;
                }
            }
        }
        self.switch(tid, None, false, true);
    }

    /// Register a new modeled thread (starts Runnable, runs when
    /// scheduled). Returns its tid.
    pub(crate) fn register(&self) -> usize {
        let mut st = self.lock();
        st.status.push(Status::Runnable);
        st.status.len() - 1
    }

    /// First wait of a freshly spawned thread: hold until the scheduler
    /// hands it the token. Returns false when the model already failed
    /// (the thread then skips its body entirely).
    fn wait_first(&self, tid: usize) -> bool {
        let mut st = self.lock();
        loop {
            if st.failed.is_some() {
                return false;
            }
            if st.active == tid && st.status[tid] == Status::Runnable {
                return true;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Mark `tid` finished, wake joiners, and pass the token on.
    fn finish(&self, tid: usize, panic_msg: Option<String>) {
        let mut st = self.lock();
        if let Some(m) = panic_msg {
            st.failed.get_or_insert(m);
        }
        st.status[tid] = Status::Finished;
        let jr = join_rid(tid);
        for s in st.status.iter_mut() {
            if *s == Status::Blocked(jr) {
                *s = Status::Runnable;
            }
        }
        if st.failed.is_none() {
            let _ = self.pick_next(&mut st, tid, true);
        }
        self.cv.notify_all();
    }

    /// Block the calling modeled thread until `target` finishes.
    pub(crate) fn join_wait(&self, tid: usize, target: usize) {
        loop {
            {
                let st = self.lock();
                if st.failed.is_some() {
                    drop(st);
                    if std::thread::panicking() {
                        return;
                    }
                    panic!("loom: model failed in another thread");
                }
                if st.status[target] == Status::Finished {
                    return;
                }
            }
            // No other modeled thread can finish `target` between the
            // check above and parking here (we hold the token).
            self.block_on(tid, join_rid(target));
        }
    }

    pub(crate) fn push_os_handle(&self, h: std::thread::JoinHandle<()>) {
        self.os_handles.lock().unwrap_or_else(|e| e.into_inner()).push(h);
    }

    pub(crate) fn wait_all_finished(&self) {
        let mut st = self.lock();
        while !st.status.iter().all(|s| matches!(s, Status::Finished)) {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    pub(crate) fn join_os_threads(&self) {
        let handles: Vec<_> =
            self.os_handles.lock().unwrap_or_else(|e| e.into_inner()).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }

    pub(crate) fn failure(&self) -> Option<String> {
        self.lock().failed.clone()
    }

    /// DFS step: the prefix for the next execution, or `None` when the
    /// whole (bounded) schedule space has been explored.
    pub(crate) fn next_prefix(&self) -> Option<Vec<usize>> {
        let st = self.lock();
        for i in (0..st.trace.len()).rev() {
            let d = &st.trace[i];
            if d.chosen + 1 < d.candidates.len() {
                let mut p: Vec<usize> =
                    st.trace[..i].iter().map(|d| d.candidates[d.chosen]).collect();
                p.push(d.candidates[d.chosen + 1]);
                return Some(p);
            }
        }
        None
    }
}

/// Spawn a modeled thread running `f`, storing its result in `slot`.
pub(crate) fn spawn_modeled<T, F>(
    sched: &Arc<Sched>,
    f: F,
    slot: Arc<Mutex<Option<T>>>,
) -> usize
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let tid = sched.register();
    let sched2 = Arc::clone(sched);
    let os = std::thread::spawn(move || {
        CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&sched2), tid)));
        if sched2.wait_first(tid) {
            match catch_unwind(AssertUnwindSafe(f)) {
                Ok(v) => {
                    *slot.lock().unwrap_or_else(|e| e.into_inner()) = Some(v);
                    sched2.finish(tid, None);
                }
                Err(p) => sched2.finish(tid, Some(payload_str(p))),
            }
        } else {
            sched2.finish(tid, None);
        }
        CURRENT.with(|c| *c.borrow_mut() = None);
    });
    sched.push_os_handle(os);
    tid
}

/// Launch the model closure as tid 0 of a fresh execution.
pub(crate) fn run_root<F>(sched: &Arc<Sched>, f: Arc<F>)
where
    F: Fn() + Send + Sync + 'static,
{
    let slot: Arc<Mutex<Option<()>>> = Arc::new(Mutex::new(None));
    let tid = spawn_modeled(sched, move || f(), slot);
    debug_assert_eq!(tid, 0);
}
