//! Vendored, dependency-free subset of the `loom` systematic concurrency
//! checker (API-compatible with tokio-rs/loom for the surface this repo
//! uses). The build is fully offline, so the real crate cannot be pulled
//! in; this reimplementation keeps the same shape — `loom::model(|| ...)`
//! plus `loom::sync` / `loom::thread` drop-ins — so the facade in
//! `rust/src/util/sync.rs` reads exactly like a standard loom setup and
//! can be swapped for the upstream crate without touching call sites.
//!
//! # How it checks
//!
//! `model(f)` runs the closure under a *token-passing* cooperative
//! scheduler: every modeled thread is a real OS thread, but only one is
//! runnable at a time. Each synchronization operation (atomic access,
//! lock acquire/release, voluntary yield) is a *scheduling point* where
//! the scheduler may hand the token to another thread. The explorer
//! enumerates schedules depth-first: execution 1 takes the first choice
//! at every point, and each subsequent execution replays a recorded
//! prefix and flips the last decision that still has untried
//! alternatives, until the space is exhausted.
//!
//! The space is kept tractable with CHESS-style *preemption bounding*:
//! at most `LOOM_MAX_PREEMPTIONS` (default 2) involuntary context
//! switches per execution. Empirically almost all real interleaving bugs
//! need very few preemptions; bound 2 finds, e.g., a publish-order
//! inversion or a lost update. Voluntary switches (blocking on a held
//! lock, `yield_now`) are never counted against the bound, so runs
//! remain complete for protocols that wait.
//!
//! # Scope and limitations
//!
//! * **Sequential consistency only.** Atomics are modeled as SeqCst
//!   regardless of the requested `Ordering`: the checker explores
//!   *interleavings*, not weak-memory reorderings. Ordering-sensitive
//!   bugs are covered separately by ThreadSanitizer (`make tsan`).
//! * Threads spawned through `std::thread` directly (not
//!   `loom::thread::spawn`) are invisible to the scheduler; modeled code
//!   must keep its parallel fan-outs at width 1 (see
//!   `tests/loom_models.rs`).
//! * A model must be deterministic given the schedule (no wall-clock
//!   branching, no RNG).
//!
//! Outside an active model (including when this crate is linked into a
//! normal, non-`--cfg loom` build), every primitive delegates straight
//! to its `std::sync` twin with the caller's orderings, so the types are
//! usable in statics and cost one branch per operation.

use std::sync::Mutex as StdMutex;

mod sched;
pub mod sync;
pub mod thread;

use sched::Sched;
use std::sync::Arc;

/// Serializes model runs: `cargo test` runs tests on parallel threads,
/// and two concurrently-exploring models would interleave their real OS
/// threads (harmless for correctness — schedulers are per-model and
/// threads are tagged with their scheduler — but serial runs keep panic
/// output readable and memory bounded).
static MODEL_SERIAL: StdMutex<()> = StdMutex::new(());

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Exhaustively model-check `f` under the default preemption bound
/// (`LOOM_MAX_PREEMPTIONS`, default 2). Panics if any explored schedule
/// panics (assertion failure in the model) or deadlocks.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    model_bounded(env_usize("LOOM_MAX_PREEMPTIONS", 2), f);
}

/// `model` with an explicit preemption bound for tests that need deeper
/// interleavings than the default.
pub fn model_bounded<F>(bound: usize, f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let _serial = MODEL_SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    assert!(sched::current().is_none(), "loom: nested model() is not supported");
    let f = Arc::new(f);
    let max_iters = env_usize("LOOM_MAX_ITERS", 200_000);
    let mut prefix: Vec<usize> = Vec::new();
    let mut iters = 0usize;
    loop {
        iters += 1;
        assert!(
            iters <= max_iters,
            "loom: schedule space exceeded LOOM_MAX_ITERS={max_iters}; \
             shrink the model or raise the cap"
        );
        let sched = Sched::new(prefix.clone(), bound);
        sched::run_root(&sched, f.clone());
        sched.wait_all_finished();
        sched.join_os_threads();
        if let Some(msg) = sched.failure() {
            panic!(
                "loom: model failed after {iters} execution(s): {msg}\n\
                 failing schedule prefix (tids at branch points): {prefix:?}"
            );
        }
        match sched.next_prefix() {
            Some(p) => prefix = p,
            None => break,
        }
    }
}

/// Number of executions a model explores (diagnostic helper for the
/// crate's own tests): runs the model to completion and returns how many
/// schedules were executed.
pub fn explore_count<F>(bound: usize, f: F) -> usize
where
    F: Fn() + Send + Sync + 'static,
{
    let _serial = MODEL_SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    assert!(sched::current().is_none(), "loom: nested model() is not supported");
    let f = Arc::new(f);
    let mut prefix: Vec<usize> = Vec::new();
    let mut iters = 0usize;
    loop {
        iters += 1;
        let sched = Sched::new(prefix.clone(), bound);
        sched::run_root(&sched, f.clone());
        sched.wait_all_finished();
        sched.join_os_threads();
        if let Some(msg) = sched.failure() {
            panic!("loom: model failed after {iters} execution(s): {msg}");
        }
        match sched.next_prefix() {
            Some(p) => prefix = p,
            None => return iters,
        }
    }
}
