//! Self-tests for the vendored checker: it must (a) find seeded
//! interleaving bugs, (b) detect deadlocks, (c) terminate on yield-based
//! spin loops, and (d) pass correct protocols. These run under the
//! normal test suite (no `--cfg loom` needed — the crate is always
//! compiled; only the facade swap is cfg-gated).

use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use loom::sync::{Arc, Mutex};
use std::panic::catch_unwind;

#[test]
fn finds_lost_update() {
    // Non-atomic read-modify-write: some interleaving loses an update.
    let r = catch_unwind(|| {
        loom::model(|| {
            let n = Arc::new(AtomicUsize::new(0));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let n = Arc::clone(&n);
                    loom::thread::spawn(move || {
                        let v = n.load(Ordering::SeqCst);
                        n.store(v + 1, Ordering::SeqCst);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(n.load(Ordering::SeqCst), 2, "lost update");
        });
    });
    assert!(r.is_err(), "the checker must find the lost update");
}

#[test]
fn mutex_counter_is_clean_and_explores_many_schedules() {
    let executions = loom::explore_count(2, || {
        let n = Arc::new(Mutex::new(0usize));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let n = Arc::clone(&n);
                loom::thread::spawn(move || {
                    *n.lock().unwrap() += 1;
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*n.lock().unwrap(), 2);
    });
    assert!(executions > 1, "expected branching, got {executions} execution(s)");
}

#[test]
fn detects_lock_order_deadlock() {
    let r = catch_unwind(|| {
        loom::model(|| {
            let a = Arc::new(Mutex::new(()));
            let b = Arc::new(Mutex::new(()));
            let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
            let t = loom::thread::spawn(move || {
                let _ga = a2.lock().unwrap();
                let _gb = b2.lock().unwrap();
            });
            {
                let _gb = b.lock().unwrap();
                let _ga = a.lock().unwrap();
            }
            let _ = t.join();
        });
    });
    assert!(r.is_err(), "the checker must find the AB/BA deadlock");
}

#[test]
fn yield_spin_loop_terminates() {
    // A reader spinning with yield_now must not hang exploration: the
    // voluntary yield always hands the token to the writer.
    loom::model(|| {
        let flag = Arc::new(AtomicBool::new(false));
        let f2 = Arc::clone(&flag);
        let t = loom::thread::spawn(move || {
            f2.store(true, Ordering::SeqCst);
        });
        while !flag.load(Ordering::SeqCst) {
            loom::thread::yield_now();
        }
        t.join().unwrap();
    });
}

#[test]
fn primitives_delegate_outside_models() {
    // No model active: the same types behave like plain std ones, usable
    // from ordinary threads and statics.
    static N: AtomicUsize = AtomicUsize::new(0);
    let m = Arc::new(Mutex::new(Vec::new()));
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let m = Arc::clone(&m);
            std::thread::spawn(move || {
                N.fetch_add(1, Ordering::Relaxed);
                m.lock().unwrap().push(i);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(N.load(Ordering::Relaxed), 4);
    let mut v = m.lock().unwrap().clone();
    v.sort_unstable();
    assert_eq!(v, vec![0, 1, 2, 3]);
}

#[test]
fn rwlock_readers_and_writer_explore_cleanly() {
    loom::model(|| {
        let l = Arc::new(loom::sync::RwLock::new(0u64));
        let l2 = Arc::clone(&l);
        let t = loom::thread::spawn(move || {
            *l2.write().unwrap() = 7;
        });
        let v = *l.read().unwrap();
        assert!(v == 0 || v == 7, "torn read: {v}");
        t.join().unwrap();
    });
}
