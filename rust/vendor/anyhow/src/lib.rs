//! Vendored, dependency-free reimplementation of the subset of `anyhow`
//! this workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics match upstream where it matters to callers:
//! * `Display` shows the outermost context (or the root message);
//! * `Debug` shows the full chain (`Caused by:` style), which is what a
//!   `fn main() -> anyhow::Result<()>` prints on error;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`;
//! * `Error` itself does **not** implement `std::error::Error` (same as
//!   upstream), which is what keeps the blanket `From` impl coherent.

use std::fmt;

/// An error chain: the root message plus the contexts wrapped around it,
/// outermost last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.push(c.to_string());
        self
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.last() {
            Some(outer) => write!(f, "{outer}"),
            None => write!(f, "unknown error"),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut it = self.chain();
        match it.next() {
            Some(outer) => write!(f, "{outer}")?,
            None => write!(f, "unknown error")?,
        }
        let rest: Vec<&str> = it.collect();
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in rest.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve source() messages as chain entries.
        let mut chain = Vec::new();
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        chain.reverse();
        chain.push(e.to_string());
        Error { chain }
    }
}

/// `anyhow::Result<T>`: like upstream, the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn debug_shows_chain() {
        let e = Error::msg("root").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("root"), "{dbg}");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_option_and_result() {
        let o: Option<u32> = None;
        assert!(o.context("missing").is_err());
        let r: Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "step 2");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        fn f(v: usize) -> Result<usize> {
            ensure!(v > 1, "too small: {v}");
            if v > 10 {
                bail!("too big: {v}");
            }
            Ok(v)
        }
        assert!(f(0).is_err());
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(11).is_err());
    }
}
