"""L1 correctness gate: Pallas kernels vs pure-jnp oracles.

Sweeps shapes and data regimes (the `hypothesis` package is not available
in this environment, so the sweep is an explicit seeded parameter grid —
same coverage intent: many shapes x dtypes x data regimes, deterministic
replay via the seed in the test id).
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels.combine import combine
from compile.kernels.flash_decode import BLOCK_K, flash_decode
from compile.kernels.ref import ref_attention, ref_combine, ref_joint


def rand_case(seed, h, s, d, scale=1.0, pad=0):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((h, d), dtype=np.float32) * scale
    k = rng.standard_normal((h, s, d), dtype=np.float32)
    v = rng.standard_normal((h, s, d), dtype=np.float32)
    mask = np.zeros((h, s), dtype=np.float32)
    if pad:
        mask[:, s - pad:] = -1e30
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(mask)


# Shape sweep: heads x seq-blocks x head-dim. S must be a BLOCK_K multiple
# (the serving static set is 640 = 5 * 128).
SHAPES = [
    (1, BLOCK_K, 64),
    (1, 5 * BLOCK_K, 192),     # induction-mini geometry
    (2, 2 * BLOCK_K, 32),
    (4, 4 * BLOCK_K, 64),
    (8, 5 * BLOCK_K, 64),      # llama3-mini geometry
    (8, BLOCK_K, 128),
    (3, 3 * BLOCK_K, 16),
]


@pytest.mark.parametrize("h,s,d", SHAPES)
@pytest.mark.parametrize("seed", [0, 1])
def test_flash_decode_matches_ref(h, s, d, seed):
    q, k, v, mask = rand_case(seed * 1000 + h * 10 + d, h, s, d)
    o, lse = flash_decode(q, k, v, mask)
    o_ref, lse_ref = ref_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("pad", [1, 63, BLOCK_K - 1, BLOCK_K, 2 * BLOCK_K])
def test_flash_decode_respects_padding_mask(pad):
    """Padded tail positions must not influence the output."""
    h, s, d = 2, 4 * BLOCK_K, 32
    q, k, v, mask = rand_case(7, h, s, d, pad=pad)
    o, lse = flash_decode(q, k, v, mask)
    # Reference computed only over the valid prefix.
    valid = s - pad
    o_ref, lse_ref = ref_attention(q, k[:, :valid], v[:, :valid],
                                   jnp.zeros((h, valid), jnp.float32))
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("scale", [1e-3, 1.0, 30.0])
def test_flash_decode_extreme_logits(scale):
    """Online softmax must stay stable for sharp and flat score regimes."""
    h, s, d = 2, 2 * BLOCK_K, 64
    q, k, v, mask = rand_case(11, h, s, d, scale=scale)
    o, lse = flash_decode(q, k, v, mask)
    assert np.isfinite(np.asarray(o)).all()
    assert np.isfinite(np.asarray(lse)).all()
    o_ref, lse_ref = ref_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("h,d", [(1, 16), (4, 64), (8, 192)])
@pytest.mark.parametrize("seed", [0, 3])
def test_combine_matches_ref(h, d, seed):
    rng = np.random.default_rng(seed)
    o1 = jnp.asarray(rng.standard_normal((h, d), dtype=np.float32))
    o2 = jnp.asarray(rng.standard_normal((h, d), dtype=np.float32))
    lse1 = jnp.asarray(rng.standard_normal(h).astype(np.float32) * 3)
    lse2 = jnp.asarray(rng.standard_normal(h).astype(np.float32) * 3)
    o, lse = combine(o1, lse1, o2, lse2)
    o_ref, lse_ref = ref_combine(o1, lse1, o2, lse2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref), rtol=1e-5, atol=1e-6)


def test_split_combine_equals_joint_attention():
    """The Appendix B.1 guarantee end-to-end at the kernel level:
    attend(W) + attend(Omega) + combine == attend(W u Omega)."""
    h, d = 4, 64
    s1, s2 = 2 * BLOCK_K, 3 * BLOCK_K
    rng = np.random.default_rng(21)
    q = jnp.asarray(rng.standard_normal((h, d), dtype=np.float32))
    k1 = jnp.asarray(rng.standard_normal((h, s1, d), dtype=np.float32))
    v1 = jnp.asarray(rng.standard_normal((h, s1, d), dtype=np.float32))
    k2 = jnp.asarray(rng.standard_normal((h, s2, d), dtype=np.float32))
    v2 = jnp.asarray(rng.standard_normal((h, s2, d), dtype=np.float32))
    z1 = jnp.zeros((h, s1), jnp.float32)
    z2 = jnp.zeros((h, s2), jnp.float32)

    o1, lse1 = flash_decode(q, k1, v1, z1)
    o2, lse2 = flash_decode(q, k2, v2, z2)
    o, lse = combine(o1, lse1, o2, lse2)

    o_ref, lse_ref = ref_joint(q, k1, v1, z1, k2, v2, z2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref), rtol=3e-5, atol=3e-5)


def test_combine_with_empty_set():
    """An empty partial (lse = -inf) must be the identity."""
    h, d = 2, 32
    rng = np.random.default_rng(5)
    o1 = jnp.asarray(rng.standard_normal((h, d), dtype=np.float32))
    lse1 = jnp.asarray(rng.standard_normal(h).astype(np.float32))
    o2 = jnp.zeros((h, d), jnp.float32)
    lse2 = jnp.full((h,), -1e30, jnp.float32)
    o, lse = combine(o1, lse1, o2, lse2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o1), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse1), rtol=1e-4, atol=1e-4)
