"""AOT pipeline checks: manifest completeness, HLO-text validity, and
numerical equivalence of the lowered computation with the eager model."""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Build artifacts for one small preset into a temp dir."""
    out = str(tmp_path_factory.mktemp("artifacts"))
    spec = model.PRESETS["induction-mini"]
    manifest = {"format": 1, "presets": {spec.name: aot.build_preset(spec, out)}}
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return out, manifest


def test_manifest_lists_all_entry_points(built):
    _, manifest = built
    spec = model.PRESETS["induction-mini"]
    arts = manifest["presets"]["induction-mini"]["artifacts"]
    assert set(arts) == set(model.entry_points(spec))
    for name, a in arts.items():
        assert a["file"].endswith(f"{name}.hlo.txt")
        assert all("shape" in s and "dtype" in s for s in a["args"])


def test_hlo_files_exist_and_parse(built):
    out, manifest = built
    from jax._src.lib import xla_client as xc
    for a in manifest["presets"]["induction-mini"]["artifacts"].values():
        path = os.path.join(out, a["file"])
        text = open(path).read()
        assert "ENTRY" in text, f"{path} does not look like HLO text"


def test_spec_block_matches_preset(built):
    _, manifest = built
    spec = model.PRESETS["induction-mini"]
    s = manifest["presets"]["induction-mini"]["spec"]
    assert s["d_model"] == spec.d_model
    assert s["q_heads"] == spec.q_heads
    assert s["static_len"] == spec.static_len
    assert s["norm"] == spec.norm


def test_lowered_combine_matches_eager(built):
    """Execute the lowered (AOT) computation via jax and compare with the
    eager function — proves the artifact computes the same thing the model
    defines (the Rust side then only needs a faithful loader)."""
    spec = model.PRESETS["induction-mini"]
    eps = model.entry_points(spec)
    fn, args = eps["combine"]
    rng = np.random.default_rng(9)
    concrete = [jnp.asarray(rng.standard_normal(a.shape, dtype=np.float32)) for a in args]
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    got = compiled(*concrete)
    want = fn(*concrete)
    for g, w in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-6)


def test_deterministic_lowering(built):
    """Lowering the same entry twice yields identical HLO text (the sha in
    the manifest is meaningful for caching)."""
    spec = model.PRESETS["induction-mini"]
    fn, args = model.entry_points(spec)["qkv_b1"]
    t1 = aot.to_hlo_text(aot.lower_entry(fn, args))
    t2 = aot.to_hlo_text(aot.lower_entry(fn, args))
    assert t1 == t2
