"""L2 correctness: model ops, GQA wiring, and the decode-step algebra."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels.ref import ref_attention


SPEC = model.PRESETS["llama3-mini"]


def rand_weights(spec, seed=0):
    rng = np.random.default_rng(seed)
    d, dh, h, kv, f = spec.d_model, spec.head_dim, spec.q_heads, spec.kv_heads, spec.ffn_dim
    w = lambda *shape: jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * 0.05)
    return {
        "g": jnp.ones((d,), jnp.float32),
        "wq": w(d, h * dh),
        "wk": w(d, kv * dh),
        "wv": w(d, kv * dh),
        "wo": w(h * dh, d),
        "g2": jnp.ones((d,), jnp.float32),
        "w1": w(d, f),
        "w3": w(d, f),
        "w2": w(f, d),
        "gf": jnp.ones((d,), jnp.float32),
        "wu": w(d, spec.vocab),
        "table": w(spec.vocab, d),
    }


def test_qkv_shapes():
    w = rand_weights(SPEC)
    x = jnp.ones((3, SPEC.d_model), jnp.float32)
    q, k, v = model.qkv(SPEC, x, w["g"], w["wq"], w["wk"], w["wv"])
    assert q.shape == (3, SPEC.q_heads, SPEC.head_dim)
    assert k.shape == (3, SPEC.kv_heads, SPEC.head_dim)
    assert v.shape == (3, SPEC.kv_heads, SPEC.head_dim)


def test_rmsnorm_unit_scale():
    x = jnp.asarray([[3.0, 4.0]])
    g = jnp.ones((2,), jnp.float32)
    y = model.rmsnorm(x, g, True)
    # RMS of [3,4] = sqrt(12.5); output RMS must be ~1.
    rms = float(jnp.sqrt(jnp.mean(y**2)))
    assert abs(rms - 1.0) < 1e-3
    # Disabled norm is the identity.
    np.testing.assert_array_equal(np.asarray(model.rmsnorm(x, g, False)), np.asarray(x))


def test_embed_lookup():
    w = rand_weights(SPEC)
    ids = jnp.asarray([5, 0, 5], jnp.int32)
    pos = jnp.zeros((3, SPEC.d_model), jnp.float32)
    x = model.embed(SPEC, w["table"], ids, pos)
    np.testing.assert_array_equal(np.asarray(x[0]), np.asarray(w["table"][5]))
    np.testing.assert_array_equal(np.asarray(x[0]), np.asarray(x[2]))
    pos1 = jnp.ones((3, SPEC.d_model), jnp.float32)
    x1 = model.embed(SPEC, w["table"], ids, pos1)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x) + 1.0)


@pytest.mark.parametrize("preset", ["llama3-mini", "yi6-mini", "induction-mini"])
def test_static_attn_matches_ref_with_gqa(preset):
    """The GQA expansion + Pallas call must equal a per-head reference."""
    spec = model.PRESETS[preset]
    rng = np.random.default_rng(3)
    s = spec.static_len
    q = jnp.asarray(rng.standard_normal((spec.q_heads, spec.head_dim), dtype=np.float32))
    k = jnp.asarray(rng.standard_normal((s, spec.kv_heads, spec.head_dim), dtype=np.float32))
    v = jnp.asarray(rng.standard_normal((s, spec.kv_heads, spec.head_dim), dtype=np.float32))
    mask = np.zeros((s,), np.float32)
    mask[s - 100:] = -1e30  # padded tail
    mask = jnp.asarray(mask)

    o, lse = model.static_attn(spec, q, k, v, mask)

    group = np.arange(spec.q_heads) // spec.group_size
    kh = jnp.asarray(np.asarray(k)[:, group, :].transpose(1, 0, 2))
    vh = jnp.asarray(np.asarray(v)[:, group, :].transpose(1, 0, 2))
    maskh = jnp.broadcast_to(mask[None, :], (spec.q_heads, s))
    scale = spec.head_dim ** -0.5
    o_ref, lse_ref = ref_attention(q * scale, kh, vh, maskh)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref), rtol=3e-5, atol=3e-5)


def test_post_attn_residual_path():
    """With zero FFN weights, post_attn must reduce to x + attn @ wo."""
    spec = SPEC
    w = rand_weights(spec)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, spec.d_model), dtype=np.float32))
    attn = jnp.asarray(
        np.random.default_rng(2).standard_normal((2, spec.q_heads * spec.head_dim), dtype=np.float32)
    )
    zero1 = jnp.zeros_like(w["w1"])
    zero3 = jnp.zeros_like(w["w3"])
    zero2 = jnp.zeros_like(w["w2"])
    y = model.post_attn(spec, x, attn, w["wo"], w["g2"], zero1, zero3, zero2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x + attn @ w["wo"]), rtol=1e-5, atol=1e-6)


def test_lm_head_logits():
    spec = SPEC
    w = rand_weights(spec)
    x = jnp.ones((1, spec.d_model), jnp.float32)
    logits = model.lm_head(spec, x, w["gf"], w["wu"])
    assert logits.shape == (1, spec.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_entry_points_cover_decode_and_prefill():
    eps = model.entry_points(SPEC)
    for required in [
        "embed_b1", "embed_b256", "qkv_b1", "qkv_b256", "post_b1",
        "post_b256", "lm_head_b1", "lm_head_b256", "static_attn", "combine",
    ]:
        assert required in eps, f"missing entry point {required}"
    # Shapes of the decode-step qkv artifact.
    fn, args = eps["qkv_b1"]
    assert tuple(args[0].shape) == (1, SPEC.d_model)
    out = fn(*[jnp.zeros(a.shape, a.dtype) for a in args])
    assert out[0].shape == (1, SPEC.q_heads, SPEC.head_dim)


def test_presets_are_consistent():
    for name, spec in model.PRESETS.items():
        assert spec.q_heads % spec.kv_heads == 0, name
        assert spec.static_len % 128 == 0, f"{name}: static_len must be BLOCK_K-aligned"
        assert spec.name == name
