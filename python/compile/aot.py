"""AOT lowering: JAX entry points -> HLO text artifacts + manifest.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: python -m compile.aot --out-dir ../artifacts [--presets a,b,...]

Python runs exactly once, here. The Rust binary is self-contained after
`make artifacts`: it reads `manifest.json` for shapes and loads the
`.hlo.txt` files through the PJRT CPU client.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, arg_specs):
    # keep_unused: presets with norm disabled never read the gain tensors,
    # but the Rust runtime feeds a fixed buffer list per artifact — the
    # entry signature must stay stable across presets.
    return jax.jit(fn, keep_unused=True).lower(*arg_specs)


def spec_json(s):
    return {"shape": list(s.shape), "dtype": s.dtype.name}


def build_preset(spec: model.ModelSpec, out_dir: str) -> dict:
    """Lower every entry point of one preset; returns its manifest stanza."""
    preset_dir = os.path.join(out_dir, spec.name)
    os.makedirs(preset_dir, exist_ok=True)
    artifacts = {}
    for name, (fn, args) in model.entry_points(spec).items():
        lowered = lower_entry(fn, args)
        text = to_hlo_text(lowered)
        rel = os.path.join(spec.name, f"{name}.hlo.txt")
        path = os.path.join(out_dir, rel)
        with open(path, "w") as f:
            f.write(text)
        outs = [spec_json(s) for s in jax.tree_util.tree_leaves(lowered.out_info)]
        artifacts[name] = {
            "file": rel,
            "args": [spec_json(s) for s in args],
            "outs": outs,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"  {spec.name}/{name}: {len(text)} chars, "
              f"{len(args)} args -> {len(outs)} outs")
    return {
        "spec": {
            "layers": spec.layers,
            "d_model": spec.d_model,
            "q_heads": spec.q_heads,
            "kv_heads": spec.kv_heads,
            "head_dim": spec.head_dim,
            "vocab": spec.vocab,
            "norm": spec.norm,
            "ffn_dim": spec.ffn_dim,
            "static_len": spec.static_len,
        },
        "artifacts": artifacts,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--presets",
        default=",".join(model.PRESETS),
        help="comma-separated preset names",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": 1, "presets": {}}
    for name in args.presets.split(","):
        spec = model.PRESETS[name]
        print(f"preset {name}:")
        manifest["presets"][name] = build_preset(spec, args.out_dir)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {args.out_dir}/manifest.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
