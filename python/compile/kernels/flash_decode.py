"""Layer-1 Pallas kernel: blocked decode attention with online softmax.

The device-side half of RetrievalAttention's CPU-GPU co-execution (§3.3):
attention of one decode query over the *static* KV set ``W`` (sink +
sliding window), emitting the partial output *and* the log-sum-exp so the
Rust coordinator can gamma-combine it with the host-side retrieved partial
(Appendix B.1, Eq. 4/5).

Hardware adaptation (DESIGN.md §3): the paper's CUDA FlashAttention tiles
HBM->shared-memory per threadblock; here the KV sequence is blocked with
``BlockSpec((BLOCK_K, d))`` so each grid step streams one KV tile
HBM->VMEM and the contraction ``q @ K_tile^T`` runs all query heads at
once — an [H, d] x [d, BLOCK_K] matmul that keeps the 128x128 MXU
occupied (H rows of systolic input instead of 1; decode attention is
bandwidth-bound either way, so the kernel's job is to keep the KV stream
saturated). The running ``(o, m, l)`` online-softmax state lives in the
revisited output blocks (their index map ignores the KV-block axis), which
Pallas keeps resident across the sequential grid — the VMEM-scratch idiom
without `scratch_shapes`, portable to ``interpret=True``.

Grid layout note (EXPERIMENTS.md §Perf, L1 iteration 2): an earlier
version used grid=(heads, blocks_k) with one query row per step; folding
the head loop into the tile matmul cut the grid from H*blocks to blocks
steps — 8x fewer interpreter dispatches on the CPU path and a strictly
better MXU shape on TPU.

All kernels in this repo are lowered with ``interpret=True``: the CPU PJRT
client cannot execute Mosaic custom-calls. Real-TPU performance is
estimated analytically in EXPERIMENTS.md §Perf (VMEM footprint / MXU
occupancy), not measured.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# KV-sequence tile. Perf iterations (EXPERIMENTS.md §Perf, L1):
#   (1) grid=(H, S/128), 1 query row/step:        17 ms/call (interpret)
#   (2) grid=(S/128,), all heads batched:          2.8 ms/call
#   (3) tile 320 -> 2 grid steps (this setting):   1.7 ms/call
# 320 keeps the cross-block online-softmax recurrence on the production
# path (tile 640 = single block would degenerate it) while the per-step
# VMEM footprint stays tiny: BLOCK_K*d*2*4B*H = 1.3MB for d=64, H=8 —
# well under the ~16MB VMEM budget, leaving room for double buffering.
# The interpreter dispatch cost per grid step is a CPU-substrate artifact;
# on real TPU the tile choice trades VMEM residency vs pipeline depth.
BLOCK_K = 320

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, *, blocks_k):
    """One KV-block grid step, all query heads at once.

    Grid is (blocks_k,), sequential. Outputs are indexed by nothing (block
    0 always), so (o, m, l) are revisited every step and carry the
    online-softmax state.
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]            # [H, d]
    k = k_ref[...]            # [H, BLOCK_K, d]
    v = v_ref[...]            # [H, BLOCK_K, d]
    mask = mask_ref[...]      # [H, BLOCK_K]

    # Scores for this tile: one batched MXU pass per head group.
    s = jnp.einsum("hd,htd->ht", q, k) + mask      # [H, BLOCK_K]

    m_prev = m_ref[...]                            # [H, 1]
    l_prev = l_ref[...]
    o_prev = o_ref[...]                            # [H, d]

    m_cur = jnp.max(s, axis=-1, keepdims=True)     # [H, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                         # [H, BLOCK_K]
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    o_new = o_prev * corr + jnp.einsum("ht,htd->hd", p, v)

    m_ref[...] = m_new
    l_ref[...] = l_new

    is_last = j == blocks_k - 1

    @pl.when(is_last)
    def _final():
        # Epilogue: normalize once at the end.
        o_ref[...] = o_new / l_new

    @pl.when(jnp.logical_not(is_last))
    def _carry():
        o_ref[...] = o_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode(q, keys, values, mask, *, interpret=True):
    """Decode attention of per-head queries over a fixed KV set.

    Args:
      q:      [H, d]      already scaled by 1/sqrt(d).
      keys:   [H, S, d]   per-head key tile (GQA groups pre-expanded by the
                          L2 wrapper via a gather, keeping the kernel dense).
      values: [H, S, d]
      mask:   [H, S]      additive mask (0 valid / -inf padding).

    Returns:
      o:   [H, d] partial attention output (normalized within the set).
      lse: [H]    log-sum-exp of the scaled logits (for gamma-combine).
    """
    h, s, d = keys.shape
    assert s % BLOCK_K == 0, f"S={s} must be a multiple of {BLOCK_K}"
    blocks_k = s // BLOCK_K

    kernel = functools.partial(_attn_kernel, blocks_k=blocks_k)
    o, m, l = pl.pallas_call(
        kernel,
        grid=(blocks_k,),
        in_specs=[
            pl.BlockSpec((h, d), lambda j: (0, 0)),            # q (all heads)
            pl.BlockSpec((h, BLOCK_K, d), lambda j: (0, j, 0)),
            pl.BlockSpec((h, BLOCK_K, d), lambda j: (0, j, 0)),
            pl.BlockSpec((h, BLOCK_K), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((h, d), lambda j: (0, 0)),            # o
            pl.BlockSpec((h, 1), lambda j: (0, 0)),            # running max
            pl.BlockSpec((h, 1), lambda j: (0, 0)),            # running sum
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, d), q.dtype),
            jax.ShapeDtypeStruct((h, 1), q.dtype),
            jax.ShapeDtypeStruct((h, 1), q.dtype),
        ],
        interpret=interpret,
    )(q, keys, values, mask)
    lse = m[:, 0] + jnp.log(l[:, 0])
    return o, lse
