"""Layer-1 Pallas kernel: exact two-set attention combination (Eq. 4/5).

Merges the device partial ``(o_W, lse_W)`` with the host partial
``(o_Omega, lse_Omega)`` using the FlashAttention-style rescaling of
Appendix B.1. The default serving path performs this merge on the host
(it is O(H*d) — trivially cheap); this kernel exists for the on-device
ablation (`bench: ablation_combine`) where the merge is fused into the
device step, and as the simplest possible Pallas example in the repo.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(o1_ref, lse1_ref, o2_ref, lse2_ref, o_ref, lse_ref):
    o1 = o1_ref[...]          # [1, d]
    o2 = o2_ref[...]
    lse1 = lse1_ref[...]      # [1, 1]
    lse2 = lse2_ref[...]

    m = jnp.maximum(lse1, lse2)
    # logaddexp with the empty-set convention: exp(-inf - -inf) -> handled
    # by clamping m away from -inf.
    m = jnp.maximum(m, -1e30)
    w1 = jnp.exp(lse1 - m)
    w2 = jnp.exp(lse2 - m)
    total = w1 + w2
    lse_ref[...] = m + jnp.log(total)
    g1 = w1 / total
    g2 = w2 / total
    o_ref[...] = o1 * g1 + o2 * g2


@functools.partial(jax.jit, static_argnames=("interpret",))
def combine(o1, lse1, o2, lse2, *, interpret=True):
    """Merge two per-head partial attentions.

    Args:
      o1, o2:     [H, d] partial outputs (normalized within their sets).
      lse1, lse2: [H]    log-sum-exp of each set's scaled logits.

    Returns:
      o:   [H, d] attention over the union of the two sets.
      lse: [H]
    """
    h, d = o1.shape
    o, lse = pl.pallas_call(
        _combine_kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, d), o1.dtype),
            jax.ShapeDtypeStruct((h, 1), o1.dtype),
        ],
        interpret=interpret,
    )(o1, lse1.reshape(h, 1), o2, lse2.reshape(h, 1))
    return o, lse[:, 0]
