"""Pure-jnp oracles for the Pallas kernels.

Every kernel in kernels/ has an exact reference here; pytest asserts
allclose between the kernel (interpret=True) and these references across
shape/dtype sweeps. This is the build-time correctness gate of the
three-layer stack.
"""

import jax.numpy as jnp


def ref_attention(q, keys, values, mask):
    """Exact decode attention with LSE, matching flash_decode's contract.

    Args:
      q:      [H, d] (pre-scaled).
      keys:   [H, S, d]
      values: [H, S, d]
      mask:   [H, S] additive.

    Returns:
      o: [H, d], lse: [H]
    """
    s = jnp.einsum("hd,hsd->hs", q, keys) + mask
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("hs,hsd->hd", p / l, values)
    lse = (m + jnp.log(l))[:, 0]
    return o, lse


def ref_combine(o1, lse1, o2, lse2):
    """Exact two-set merge (Eq. 4/5)."""
    m = jnp.maximum(jnp.maximum(lse1, lse2), -1e30)
    w1 = jnp.exp(lse1 - m)
    w2 = jnp.exp(lse2 - m)
    total = w1 + w2
    g1 = (w1 / total)[:, None]
    g2 = (w2 / total)[:, None]
    return o1 * g1 + o2 * g2, m + jnp.log(total)


def ref_joint(q, k1, v1, mask1, k2, v2, mask2):
    """Attention over the union of two disjoint KV sets — the ground truth
    that combine(ref_attention(set1), ref_attention(set2)) must equal."""
    keys = jnp.concatenate([k1, k2], axis=1)
    values = jnp.concatenate([v1, v2], axis=1)
    mask = jnp.concatenate([mask1, mask2], axis=1)
    return ref_attention(q, keys, values, mask)
