"""Layer-2: the transformer compute graph in JAX.

Defines the model ops the Rust coordinator drives at serve time, all as
pure functions of (weights, activations) so a single AOT artifact per op
serves every layer — weights are runtime inputs, not baked constants.
This is what lets the Rust side construct weights itself (including the
hand-built induction-head model used for end-to-end task accuracy) while
the compute graph stays fixed.

Architecture (llama-style, knobs per preset):
  * pre-norm RMSNorm (disable-able: the induction construction needs raw
    residual-stream algebra),
  * GQA attention with head_dim d_h, H query heads, KV kv-heads,
  * SwiGLU FFN,
  * positions are *additive codes baked into the embedding table
    construction on the Rust side* (no RoPE in the graph — the induction
    construction derives its layer-1 shift from rotation-equivariant
    position codes, see rust/src/model/induction.rs).

The attention over the device-resident static set W goes through the
Pallas `flash_decode` kernel so that the paper's kernel is on the real
execution path of every decode step.
"""

import dataclasses

import jax
import jax.numpy as jnp

from compile.kernels.combine import combine as pallas_combine
from compile.kernels.flash_decode import flash_decode


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Geometry of one served model preset."""

    name: str
    layers: int
    d_model: int
    q_heads: int
    kv_heads: int
    head_dim: int
    vocab: int
    norm: bool
    ffn_dim: int
    # Device static-set size (sink + window), the S of flash_decode.
    static_len: int

    @property
    def group_size(self) -> int:
        assert self.q_heads % self.kv_heads == 0
        return self.q_heads // self.kv_heads


# The model presets served by the Rust coordinator. Head-dim 64 matches the
# paper's models; layer/head counts are scaled (DESIGN.md §2 substitutions).
PRESETS = {
    # Hand-constructed induction-head model: 2 attention layers, single
    # head, no norm, inert FFN. Solves associative recall exactly, which is
    # what turns retrieval recall into task accuracy in Tables 2/3/5.
    "induction-mini": ModelSpec(
        name="induction-mini",
        layers=2,
        d_model=192,
        q_heads=1,
        kv_heads=1,
        head_dim=192,
        vocab=4096,
        norm=False,
        ffn_dim=8,
        static_len=640,
    ),
    # Llama-3-8B-like geometry, scaled: GQA 8Q/2KV, head dim 64.
    "llama3-mini": ModelSpec(
        name="llama3-mini",
        layers=4,
        d_model=512,
        q_heads=8,
        kv_heads=2,
        head_dim=64,
        vocab=8192,
        norm=True,
        ffn_dim=1024,
        static_len=640,
    ),
    # Yi-6B-like: wider GQA ratio (8Q/1KV).
    "yi6-mini": ModelSpec(
        name="yi6-mini",
        layers=4,
        d_model=512,
        q_heads=8,
        kv_heads=1,
        head_dim=64,
        vocab=8192,
        norm=True,
        ffn_dim=1024,
        static_len=640,
    ),
    # Yi-9B-like: deeper.
    "yi9-mini": ModelSpec(
        name="yi9-mini",
        layers=6,
        d_model=512,
        q_heads=8,
        kv_heads=1,
        head_dim=64,
        vocab=8192,
        norm=True,
        ffn_dim=1024,
        static_len=640,
    ),
}


def rmsnorm(x, g, enabled: bool):
    if not enabled:
        return x
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * g


def embed(spec: ModelSpec, table, ids, pos):
    """Token embedding lookup plus additive position code.

    table: [vocab, d_model], ids: [B] int32, pos: [B, d_model].

    Position codes are computed by the Rust coordinator (they are a pure
    function of the absolute position — sinusoidal planes for the induction
    construction, zeros for the random presets) and added on-device here,
    keeping the embedding artifact position-agnostic.
    """
    return jnp.take(table, ids, axis=0) + pos


def qkv(spec: ModelSpec, x, g, wq, wk, wv):
    """Pre-norm QKV projection.

    x: [B, d_model] -> q: [B, H, d_h], k: [B, KV, d_h], v: [B, KV, d_h].
    """
    b = x.shape[0]
    xn = rmsnorm(x, g, spec.norm)
    q = (xn @ wq).reshape(b, spec.q_heads, spec.head_dim)
    k = (xn @ wk).reshape(b, spec.kv_heads, spec.head_dim)
    v = (xn @ wv).reshape(b, spec.kv_heads, spec.head_dim)
    return q, k, v


def static_attn(spec: ModelSpec, q, keys, values, mask):
    """Device-side partial attention over the static set W (Algorithm 1 #6).

    q:    [H, d_h] — one decode step's query heads (unscaled).
    keys: [S, KV, d_h], values: [S, KV, d_h] — the W tile (padded to S).
    mask: [S] additive (0 valid / -1e30 padding).

    Returns (o: [H, d_h], lse: [H]) for the gamma-combine.
    """
    scale = spec.head_dim ** -0.5
    # GQA: expand KV groups to query heads (gather, no copy after fusion).
    group = jnp.arange(spec.q_heads) // spec.group_size          # [H]
    kh = jnp.take(keys, group, axis=1).transpose(1, 0, 2)        # [H, S, d_h]
    vh = jnp.take(values, group, axis=1).transpose(1, 0, 2)
    maskh = jnp.broadcast_to(mask[None, :], (spec.q_heads, mask.shape[0]))
    return flash_decode(q * scale, kh, vh, maskh)


def combine(o1, lse1, o2, lse2):
    """Exact two-set merge (Eq. 4/5) via the Pallas combine kernel."""
    return pallas_combine(o1, lse1, o2, lse2)


def post_attn(spec: ModelSpec, x, attn, wo, g2, w1, w3, w2):
    """Output projection + residual + SwiGLU FFN.

    x: [B, d_model], attn: [B, H*d_h] (flattened head outputs).
    """
    h = x + attn @ wo
    hn = rmsnorm(h, g2, spec.norm)
    ffn = (jax.nn.silu(hn @ w1) * (hn @ w3)) @ w2
    return h + ffn


def lm_head(spec: ModelSpec, x, gf, wu):
    """Final norm + unembedding. x: [B, d_model] -> logits [B, vocab]."""
    return rmsnorm(x, gf, spec.norm) @ wu


# ----------------------------------------------------------------------------
# Entry points for AOT lowering. Each artifact is (jax function, example
# argument specs); aot.py lowers them to HLO text + manifest entries.
# ----------------------------------------------------------------------------


def entry_points(spec: ModelSpec, batches=(1, 256)):
    """All artifacts for one preset: name -> (fn, [ShapeDtypeStruct...])."""
    f32 = jnp.float32
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    d, dh, h, kv, f, s, v = (
        spec.d_model,
        spec.head_dim,
        spec.q_heads,
        spec.kv_heads,
        spec.ffn_dim,
        spec.static_len,
        spec.vocab,
    )
    eps = {}
    for b in batches:
        eps[f"embed_b{b}"] = (
            lambda table, ids, pos: (embed(spec, table, ids, pos),),
            [sd((v, d), f32), sd((b,), i32), sd((b, d), f32)],
        )
        eps[f"qkv_b{b}"] = (
            lambda x, g, wq, wk, wv: qkv(spec, x, g, wq, wk, wv),
            [
                sd((b, d), f32),
                sd((d,), f32),
                sd((d, h * dh), f32),
                sd((d, kv * dh), f32),
                sd((d, kv * dh), f32),
            ],
        )
        eps[f"post_b{b}"] = (
            lambda x, attn, wo, g2, w1, w3, w2: (
                post_attn(spec, x, attn, wo, g2, w1, w3, w2),
            ),
            [
                sd((b, d), f32),
                sd((b, h * dh), f32),
                sd((h * dh, d), f32),
                sd((d,), f32),
                sd((d, f), f32),
                sd((d, f), f32),
                sd((f, d), f32),
            ],
        )
        eps[f"lm_head_b{b}"] = (
            lambda x, gf, wu: (lm_head(spec, x, gf, wu),),
            [sd((b, d), f32), sd((d,), f32), sd((d, v), f32)],
        )
    eps["static_attn"] = (
        lambda q, k, val, m: static_attn(spec, q, k, val, m),
        [sd((h, dh), f32), sd((s, kv, dh), f32), sd((s, kv, dh), f32), sd((s,), f32)],
    )
    eps["combine"] = (
        lambda o1, l1, o2, l2: combine(o1, l1, o2, l2),
        [sd((h, dh), f32), sd((h,), f32), sd((h, dh), f32), sd((h,), f32)],
    )
    return eps
