//! Index explorer: build all four ANNS indexes on attention-shaped
//! geometry and compare recall-vs-scan tradeoffs interactively.
//!
//! ```bash
//! cargo run --release --example index_explorer -- [keys] [queries-direction]
//! # e.g. 65536 qk   (default: 16384 qk)
//! ```

use retrieval_attention::index::{
    exact_topk, flat::FlatIndex, hnsw::{HnswIndex, HnswParams}, ivf::IvfIndex,
    roargraph::{RoarGraph, RoarParams}, SearchParams, VectorIndex,
};
use retrieval_attention::tensor::Matrix;
use retrieval_attention::workload::geometry::{generate, GeometryParams};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(16384);
    let dir = args.get(1).map(|s| s.as_str()).unwrap_or("qk");
    let nq = 32;

    println!("generating {n} keys of attention geometry ...");
    let g = generate(&GeometryParams::default(), n + nq, 2048 + nq, 42);
    let keys = Arc::new(Matrix::from_fn(n, 64, |r, c| g.keys[(r, c)]));
    let queries = if dir == "kk" {
        println!("direction: K->K (in-distribution)");
        Matrix::from_fn(nq, 64, |r, c| g.keys[(n + r, c)])
    } else {
        println!("direction: Q->K (the OOD case the paper targets)");
        Matrix::from_fn(nq, 64, |r, c| g.queries[(r, c)])
    };
    let train = Matrix::from_fn(2048, 64, |r, c| g.queries[(nq + r, c)]);

    println!("building indexes ...");
    let t = std::time::Instant::now();
    let flat = FlatIndex::new(keys.clone());
    println!("  Flat: {:.1}s", t.elapsed().as_secs_f64());
    let t = std::time::Instant::now();
    let ivf = IvfIndex::build(keys.clone(), None, 1);
    println!("  IVF ({} lists): {:.1}s", ivf.nlist(), t.elapsed().as_secs_f64());
    let t = std::time::Instant::now();
    let hnsw = HnswIndex::build(keys.clone(), HnswParams::default());
    println!("  HNSW: {:.1}s", t.elapsed().as_secs_f64());
    let t = std::time::Instant::now();
    let roar = RoarGraph::build(keys.clone(), &train, RoarParams::default());
    println!(
        "  RoarGraph (attention-aware, avg degree {:.1}): {:.1}s",
        roar.avg_degree(),
        t.elapsed().as_secs_f64()
    );

    let truths: Vec<Vec<u32>> =
        (0..nq).map(|qi| exact_topk(&keys, queries.row(qi), 100)).collect();

    println!("\n{:<20} {:>10} {:>12} {:>10}", "index", "knob", "scan %", "recall@100");
    let eval = |index: &dyn VectorIndex, knob: &str, p: SearchParams| {
        let mut recall = 0.0;
        let mut scanned = 0usize;
        for (qi, truth) in truths.iter().enumerate() {
            let r = index.search(queries.row(qi), 100, &p);
            recall += r.recall_against(truth);
            scanned += r.scanned;
        }
        println!(
            "{:<20} {:>10} {:>11.2}% {:>10.3}",
            index.name(),
            knob,
            100.0 * scanned as f64 / (nq * n) as f64,
            recall / nq as f32
        );
    };
    eval(&flat, "-", SearchParams::default());
    for nprobe in [4usize, 32, 128] {
        eval(&ivf, &format!("np={nprobe}"), SearchParams { ef: 0, nprobe });
    }
    for ef in [128usize, 512] {
        eval(&hnsw, &format!("ef={ef}"), SearchParams { ef, nprobe: 0 });
    }
    for ef in [128usize, 512] {
        eval(&roar, &format!("ef={ef}"), SearchParams { ef, nprobe: 0 });
    }
    println!(
        "\npaper shape: on Q->K, RoarGraph reaches recall >=0.95 at a scan \
         fraction conventional indexes need 10-30x more scanning for."
    );
}
