//! Serving over the network: start the json-lines TCP server on an
//! ephemeral port, connect a client, stream a generation.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_longcontext
//! ```

use retrieval_attention::config::{Method, ServeConfig};
use retrieval_attention::coordinator::router::Router;
use retrieval_attention::kvcache::StaticPattern;
use retrieval_attention::server::{Client, Server};
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::tasks;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = Method::RetrievalAttention;
    cfg.pattern = StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;

    // Two replicas behind the least-outstanding router.
    let router = Arc::new(Router::spawn(cfg, 2));
    let server = Server::start(router.clone(), "127.0.0.1:0")?;
    println!("server listening on {} with {} replicas", server.addr, router.replica_count());

    // Two concurrent clients, each with its own prompt.
    let addr = server.addr;
    let handles: Vec<_> = (0..2u64)
        .map(|cid| {
            std::thread::spawn(move || -> anyhow::Result<()> {
                let mut rng = Rng::seed_from(100 + cid);
                let sample = tasks::kv_retrieval(&mut rng, 1536, 96);
                let mut client = Client::connect(addr)?;
                let t = std::time::Instant::now();
                let (tokens, done) = client.generate(&sample.prompt, sample.expect.len())?;
                println!(
                    "client {cid}: {} tokens in {:.2}s, grade {:.0}%, ttft {:.2}s, search share {:.0}%",
                    tokens.len(),
                    t.elapsed().as_secs_f64(),
                    sample.grade(&tokens) * 100.0,
                    done.req_f64("ttft_s")?,
                    done.req_f64("search_share")? * 100.0,
                );
                Ok(())
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread")?;
    }
    println!("all clients done; shutting down");
    Ok(())
}
