//! Quickstart: load the engine, prefill a long prompt, decode with
//! attention-aware retrieval, and check the answer.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use retrieval_attention::config::{Method, ServeConfig};
use retrieval_attention::kvcache::StaticPattern;
use retrieval_attention::model::Engine;
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::tasks;

fn main() -> anyhow::Result<()> {
    // 1. Configure: the induction-mini preset + RetrievalAttention.
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = Method::RetrievalAttention;
    cfg.pattern = StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;

    // 2. Load artifacts and build the engine (weights are constructed on
    //    the Rust side; the compute graph is the AOT-compiled JAX model).
    let engine = Engine::from_config(cfg)?;
    println!(
        "loaded {} ({} params) on PJRT `{}`",
        engine.rt.preset(),
        engine.weights.param_count(),
        engine.rt.platform()
    );

    // 3. A 4K-token pass-key prompt: the needle hides at depth 40%.
    let mut rng = Rng::seed_from(1);
    let sample = tasks::passkey(&mut rng, 4096, 0.4);
    println!("prompt: {} tokens, expected answer {:?}", sample.prompt.len(), sample.expect);

    // 4. Prefill (builds the per-head RoarGraph indexes from the prefill
    //    query vectors) and decode.
    let t = std::time::Instant::now();
    let mut sess = engine.prefill(&sample.prompt)?;
    println!("prefill + index build: {:.2}s", t.elapsed().as_secs_f64());

    let (tokens, breakdown) = engine.generate(&mut sess, sample.expect.len())?;
    println!("generated {:?} -> grade {:.0}%", tokens, sample.grade(&tokens) * 100.0);
    println!(
        "decode breakdown: search {:.1}ms | attention {:.1}ms | other {:.1}ms (search share {:.0}%)",
        breakdown.search * 1e3,
        breakdown.attention * 1e3,
        breakdown.other * 1e3,
        breakdown.search_share() * 100.0
    );
    println!(
        "host index scanned {:.1}% of keys per retrieval",
        100.0 * sess.mean_scanned() / sample.prompt.len() as f64
    );
    Ok(())
}
