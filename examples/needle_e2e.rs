//! End-to-end validation driver (EXPERIMENTS.md §E2E): serve a batch of
//! real long-context requests through the full stack — router → replica
//! scheduler → engine (AOT artifacts on PJRT) → tiered KV + RoarGraph —
//! and report accuracy, latency and throughput, method by method.
//!
//! ```bash
//! make artifacts && cargo run --release --example needle_e2e [-- full]
//! ```

use retrieval_attention::config::{Method, ServeConfig};
use retrieval_attention::coordinator::{collect, router::Router, Request};
use retrieval_attention::kvcache::StaticPattern;
use retrieval_attention::metrics::LatencyHistogram;
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::tasks;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "full");
    let len = if full { 8192 } else { 2048 };
    let n_requests = if full { 16 } else { 6 };

    println!("=== RetrievalAttention end-to-end serving driver ===");
    println!("workload: {n_requests} mixed requests @ {len} tokens (passkey / KV / multi-hop)\n");

    let mut results: Vec<(String, f32, f64, f64, f64)> = Vec::new();
    for method in [Method::RetrievalAttention, Method::Flat, Method::StreamingLlm] {
        let mut cfg = ServeConfig::default();
        cfg.model = "induction-mini".into();
        cfg.method = method;
        cfg.pattern = StaticPattern { sink: 32, window: 128 };
        cfg.retrieval.top_k = 32;
        cfg.scheduler.max_batch = 4;

        // One replica; the router API is the same one `serve` exposes.
        let router = Router::spawn(cfg, 1);

        let mut rng = Rng::seed_from(7);
        let samples: Vec<_> = (0..n_requests)
            .map(|i| match i % 3 {
                0 => {
                    let depth = 0.1 + 0.8 * rng.f32();
                    tasks::passkey(&mut rng, len, depth)
                }
                1 => tasks::kv_retrieval(&mut rng, len, len / 16),
                _ => tasks::ruler_variable_tracking(&mut rng, len, 2),
            })
            .collect();

        let t0 = Instant::now();
        // Submit everything up front: the replica's continuous batcher
        // interleaves decodes across sessions.
        let receivers: Vec<_> = samples
            .iter()
            .map(|s| {
                router.submit(Request {
                    id: router.next_request_id(),
                    prompt: s.prompt.clone(),
                    max_tokens: s.expect.len(),
                    session: None,
                })
            })
            .collect();

        let mut grade = 0.0f32;
        let mut ttft = LatencyHistogram::default();
        let mut tpot = LatencyHistogram::default();
        let mut out_tokens = 0usize;
        for (rx, s) in receivers.iter().zip(samples.iter()) {
            let (tokens, m) = collect(rx)?;
            grade += s.grade(&tokens);
            ttft.record_secs(m.ttft_s);
            tpot.record_secs(m.tpot_s);
            out_tokens += m.output_tokens;
        }
        let wall = t0.elapsed().as_secs_f64();
        let acc = 100.0 * grade / n_requests as f32;
        println!(
            "{:<20} acc {:>5.1}% | ttft p50 {:>6.2}s | tpot p50 {:>7.4}s | {:>5.2} tok/s end-to-end",
            method.label(),
            acc,
            ttft.p50(),
            tpot.p50(),
            out_tokens as f64 / wall
        );
        results.push((method.label().into(), acc, ttft.p50(), tpot.p50(), out_tokens as f64 / wall));
    }

    // The paper's headline shape, asserted.
    let ra = results.iter().find(|r| r.0 == "RetrievalAttention").unwrap();
    let flat = results.iter().find(|r| r.0 == "Flat").unwrap();
    let stream = results.iter().find(|r| r.0 == "StreamingLLM").unwrap();
    println!("\nchecks:");
    println!(
        "  accuracy: ours {:.0}% vs StreamingLLM {:.0}%  {}",
        ra.1,
        stream.1,
        if ra.1 > stream.1 + 20.0 { "OK (paper: dynamic >> static)" } else { "UNEXPECTED" }
    );
    println!(
        "  tpot: ours {:.4}s vs Flat {:.4}s  {}",
        ra.3,
        flat.3,
        if ra.3 <= flat.3 { "OK (paper: ours faster than exact KNN)" } else { "UNEXPECTED (small-context regime)" }
    );
    Ok(())
}
