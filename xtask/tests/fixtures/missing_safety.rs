// Linter fixture: unsafe with and without justification. Never compiled.

pub fn bad_block(p: *const u8) -> u8 {
    unsafe { *p }
}

pub fn good_block(p: *const u8) -> u8 {
    // SAFETY: the caller guarantees p is valid (fixture).
    unsafe { *p }
}

/// Reads a raw pointer.
///
/// # Safety
/// `p` must be valid for reads.
pub unsafe fn good_fn(p: *const u8) -> u8 {
    *p
}

pub unsafe fn bad_fn(p: *const u8) -> u8 {
    *p
}

struct Wrapper(*mut u8);

// SAFETY: fixture — the pointer is never aliased.
unsafe impl Send for Wrapper {}

unsafe impl Sync for Wrapper {}

pub fn trailing(p: *const u8) -> u8 {
    unsafe { *p } // SAFETY: same-line justification counts.
}

// SAFETY: attributes between the comment and the item keep adjacency.
#[inline]
pub unsafe fn attr_between(p: *const u8) -> u8 {
    *p
}
