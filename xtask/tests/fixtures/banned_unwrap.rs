// Linter fixture: panics on serving paths. Linted as model/... and as
// util/... to exercise both sides of the directory rule.

pub fn bad_unwrap(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn bad_expect(v: Option<u32>) -> u32 {
    v.expect("fixture")
}

pub fn fine_unwrap_or(v: Option<u32>) -> u32 {
    v.unwrap_or(0)
}

pub fn fine_unwrap_or_else(v: Option<u32>) -> u32 {
    v.unwrap_or_else(|| 0)
}

pub fn fine_expect_err(v: Result<(), u32>) -> u32 {
    v.expect_err("fixture")
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
        let r: Result<u32, ()> = Ok(2);
        assert_eq!(r.expect("fine in tests"), 2);
    }
}
