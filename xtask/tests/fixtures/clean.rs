// Linter fixture: decoys that must NOT fire any rule.
//
// This comment mentions .unwrap() and .expect("...") and unsafe and
// std::sync::atomic and Ordering::Relaxed — all masked.

pub fn strings<'a>(s: &'a str) -> String {
    let _lifetime: &'a str = s;
    let _char = 'u';
    let _quote = '"';
    let _escaped = '\'';
    let msg = "calling .unwrap() inside a string is unsafe, allegedly";
    let raw = r#"std::sync::atomic::AtomicBool and "quoted" Ordering::Relaxed"#;
    let bytes = b"unsafe .expect(";
    /* block comments may mention unsafe too,
    even across lines: .unwrap() */
    format!("{msg}{raw}{}", bytes.len())
}

pub fn unsafety_is_not_unsafe(unsafety: u32) -> u32 {
    // The word boundary matters: `unsafety` contains `unsafe`.
    unsafety + 1
}
