//! Fixture for the `bare-print` rule: `println!` / `eprintln!` in
//! non-test library code outside the print allowlist.

pub fn bad_stdout(n: usize) {
    println!("processed {n} rows");
}

pub fn bad_stderr(err: &str) {
    eprintln!("warning: {err}");
}

pub fn fine_string_decoy() -> &'static str {
    // A decoy inside a string must not fire: the masked source blanks
    // literals before the rules run.
    "println!(\"not a call site\")"
}

pub fn fine_writeln(w: &mut impl std::fmt::Write) {
    // Explicit sinks are fine — the rule targets the process-global
    // stdout/stderr macros only.
    let _ = writeln!(w, "routed output");
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_print() {
        println!("test diagnostics are exempt");
        eprintln!("so is stderr in tests");
    }
}
