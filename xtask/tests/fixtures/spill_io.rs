//! Fixture for the `spill-direct-io` rule: raw `std::fs::` under
//! `store/` outside the spill facade.

use anyhow::Result;

pub fn bad_direct_write(path: &std::path::Path) -> Result<()> {
    // Bypasses atomic publication: flagged when this file sits under
    // store/ (outside store/spill.rs).
    std::fs::write(path, b"snapshot")?;
    Ok(())
}

pub fn bad_direct_remove(path: &std::path::Path) {
    std::fs::remove_file(path).ok();
}

pub fn fine_no_io() -> u32 {
    // A decoy in a string must not fire: "std::fs::write".
    7
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_helpers_may_touch_fs() {
        std::fs::read_to_string("/dev/null").ok();
    }
}
