// Linter fixture: direct std::sync primitives outside the facade.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::RwLock;

pub static COUNT: AtomicUsize = AtomicUsize::new(0);

pub fn bump() -> usize {
    COUNT.fetch_add(1, Ordering::SeqCst)
}

pub fn lock(l: &RwLock<u32>) -> u32 {
    *l.read().unwrap()
}

pub fn qualified() -> std::sync::atomic::AtomicBool {
    std::sync::atomic::AtomicBool::new(false)
}
