// Linter fixture: Relaxed ordering outside the allowlist.

use crate::util::sync::{AtomicU64, Ordering};

pub static HITS: AtomicU64 = AtomicU64::new(0);

pub fn record() {
    HITS.fetch_add(1, Ordering::Relaxed);
}

pub fn read() -> u64 {
    HITS.load(Ordering::Acquire)
}
