//! Fixture tests for the invariant linter, plus the tree gate: the real
//! `rust/src` must be lint-clean, enforced on every `cargo test` (tier-1),
//! not just when CI remembers to run `cargo xtask lint`.

use xtask::lint::{lint_source, lint_tree, Violation};

fn rules<'a>(v: &'a [Violation], rule: &str) -> Vec<&'a Violation> {
    v.iter().filter(|v| v.rule == rule).collect()
}

#[test]
fn missing_safety_fixture() {
    let src = include_str!("fixtures/missing_safety.rs");
    let v = lint_source("kernel/missing_safety.rs", src);
    let unsafe_v = rules(&v, "unsafe-no-safety");
    // bad_block's block, bad_fn's declaration, and the uncommented
    // `unsafe impl Sync` — and nothing else.
    assert_eq!(unsafe_v.len(), 3, "got: {v:?}");
    let lines: Vec<usize> = unsafe_v.iter().map(|v| v.line).collect();
    let text: Vec<&str> = src.lines().collect();
    for &ln in &lines {
        let l = text[ln - 1];
        assert!(
            l.contains("unsafe"),
            "violation line {ln} does not contain unsafe: {l}"
        );
    }
    // The justified sites are specifically absent.
    for (ln, l) in text.iter().enumerate() {
        if l.contains("good_block") || l.contains("good_fn") || l.contains("attr_between") {
            assert!(!lines.contains(&(ln + 1)), "justified site flagged at {}", ln + 1);
        }
    }
    assert!(rules(&v, "banned-unwrap").is_empty());
}

#[test]
fn stray_atomic_fixture() {
    let src = include_str!("fixtures/stray_atomic.rs");
    let v = lint_source("index/stray_atomic.rs", src);
    let stray = rules(&v, "stray-std-sync");
    // The two imports and the two fully-qualified uses.
    assert_eq!(stray.len(), 4, "got: {v:?}");
    // The same source inside the facade file is exempt.
    let facade = lint_source("util/sync.rs", src);
    assert!(rules(&facade, "stray-std-sync").is_empty());
}

#[test]
fn banned_unwrap_fixture() {
    let src = include_str!("fixtures/banned_unwrap.rs");
    let v = lint_source("model/banned_unwrap.rs", src);
    let banned = rules(&v, "banned-unwrap");
    // bad_unwrap + bad_expect; the unwrap_or/unwrap_or_else/expect_err
    // variants and the #[cfg(test)] module are exempt.
    assert_eq!(banned.len(), 2, "got: {v:?}");
    let text: Vec<&str> = src.lines().collect();
    for viol in &banned {
        assert!(
            text[viol.line - 1].contains(".unwrap()") || text[viol.line - 1].contains(".expect("),
            "bogus line {}",
            viol.line
        );
        assert!(
            !text[viol.line - 1].contains("fine_"),
            "exempt form flagged at {}",
            viol.line
        );
    }
    // Outside the banned directories the same code is fine.
    let outside = lint_source("util/banned_unwrap.rs", src);
    assert!(rules(&outside, "banned-unwrap").is_empty());
    // Every banned directory root triggers the rule.
    for dir in ["model/", "coordinator/", "server/", "store/"] {
        let v = lint_source(&format!("{dir}x.rs"), "fn f(v: Option<u32>) -> u32 { v.unwrap() }\n");
        assert_eq!(rules(&v, "banned-unwrap").len(), 1, "{dir}");
    }
}

#[test]
fn relaxed_fixture() {
    let src = include_str!("fixtures/relaxed.rs");
    let v = lint_source("model/relaxed.rs", src);
    // Only the Relaxed line — the Acquire load is fine anywhere.
    assert_eq!(rules(&v, "relaxed-ordering").len(), 1, "got: {v:?}");
    // Allowlisted files may use Relaxed.
    let allowed = lint_source("util/parallel.rs", src);
    assert!(rules(&allowed, "relaxed-ordering").is_empty());
}

#[test]
fn spill_io_fixture() {
    let src = include_str!("fixtures/spill_io.rs");
    let v = lint_source("store/spill_io.rs", src);
    let direct = rules(&v, "spill-direct-io");
    // The two raw std::fs:: calls; the string decoy and the
    // #[cfg(test)] module are exempt.
    assert_eq!(direct.len(), 2, "got: {v:?}");
    let text: Vec<&str> = src.lines().collect();
    for viol in &direct {
        assert!(text[viol.line - 1].contains("std::fs::"), "bogus line {}", viol.line);
    }
    // The spill facade itself is exempt...
    let facade = lint_source("store/spill.rs", src);
    assert!(rules(&facade, "spill-direct-io").is_empty());
    // ...and so is everything outside store/.
    let outside = lint_source("model/spill_io.rs", src);
    assert!(rules(&outside, "spill-direct-io").is_empty());
}

#[test]
fn bare_print_fixture() {
    let src = include_str!("fixtures/bare_print.rs");
    let v = lint_source("index/bare_print.rs", src);
    let bare = rules(&v, "bare-print");
    // bad_stdout + bad_stderr; the string decoy, the writeln! sink, and
    // the #[cfg(test)] module are exempt.
    assert_eq!(bare.len(), 2, "got: {v:?}");
    let text: Vec<&str> = src.lines().collect();
    for viol in &bare {
        assert!(
            text[viol.line - 1].contains("println!") || text[viol.line - 1].contains("eprintln!"),
            "bogus line {}",
            viol.line
        );
        assert!(!text[viol.line - 1].contains("fine_"), "exempt form flagged at {}", viol.line);
    }
    // Every allowlisted prefix is exempt.
    for path in ["main.rs", "experiments/tables.rs", "util/bench.rs", "telemetry/mod.rs"] {
        let allowed = lint_source(path, src);
        assert!(rules(&allowed, "bare-print").is_empty(), "{path} should be allowlisted");
    }
}

#[test]
fn clean_fixture_has_no_violations() {
    let src = include_str!("fixtures/clean.rs");
    let v = lint_source("model/clean.rs", src);
    assert!(v.is_empty(), "decoys fired: {v:?}");
}

#[test]
fn masking_strips_comments_and_strings_only() {
    use xtask::lint::mask;
    let src = "let a = \"unsafe\"; // unsafe\nlet b = r#\"x\"y\"#; /* .unwrap() */ let c = 'x';\n";
    let m = mask(src);
    assert!(!m.contains("unsafe"));
    assert!(!m.contains(".unwrap()"));
    assert!(m.contains("let a"));
    assert!(m.contains("let b"));
    assert!(m.contains("let c"));
    // Line structure is preserved for stable line numbers.
    assert_eq!(m.lines().count(), src.lines().count());
}

/// The tree gate: rust/src itself must be lint-clean. This runs in plain
/// `cargo test` (tier-1), so a violation fails the suite even if nobody
/// runs `cargo xtask lint`.
#[test]
fn tree_is_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../rust/src");
    let violations = lint_tree(&root).expect("rust/src must be readable");
    assert!(
        violations.is_empty(),
        "rust/src has lint violations:\n{}",
        violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
    );
}
