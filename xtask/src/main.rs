//! `cargo xtask <task>` — repo task runner.
//!
//! Tasks:
//! * `lint` — run the concurrency/unsafe invariant linter over `rust/src`
//!   (see `xtask/src/lint.rs` and `docs/concurrency.md`). Exits non-zero
//!   on any violation; CI runs this on every push.

use std::path::PathBuf;
use std::process::ExitCode;

fn lint_root() -> PathBuf {
    // xtask lives at <repo>/xtask; the linted tree is <repo>/rust/src.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("rust").join("src")
}

fn run_lint() -> ExitCode {
    let root = lint_root();
    match xtask::lint::lint_tree(&root) {
        Ok(violations) if violations.is_empty() => {
            eprintln!("xtask lint: clean ({})", root.display());
            ExitCode::SUCCESS
        }
        Ok(violations) => {
            for v in &violations {
                eprintln!("{v}");
            }
            eprintln!("xtask lint: {} violation(s)", violations.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("xtask lint: cannot read {}: {e}", root.display());
            ExitCode::FAILURE
        }
    }
}

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") => run_lint(),
        other => {
            eprintln!(
                "usage: cargo xtask <task>\n\ntasks:\n  lint   run the repo invariant linter \
                 over rust/src\n\nunknown task: {:?}",
                other.unwrap_or("<none>")
            );
            ExitCode::from(2)
        }
    }
}
