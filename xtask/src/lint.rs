//! The repo invariant linter: lexical rules the type system cannot carry.
//!
//! Six rules, each encoding a decision documented in
//! `docs/concurrency.md` (rules 1-4), `docs/robustness.md` (rule 5),
//! and `docs/observability.md` (rule 6):
//!
//! 1. **`unsafe` needs a justification.** Every `unsafe` token must sit
//!    next to a `// SAFETY:` comment (same line, or in the contiguous
//!    comment/attribute block directly above). `unsafe fn` declarations
//!    may instead carry a `/// # Safety` doc section — that is the public
//!    contract form.
//! 2. **The sync facade is the only door.** `std::sync::atomic` and
//!    `std::sync::RwLock` may be named only inside `util/sync.rs`;
//!    everything else imports `crate::util::sync` so `--cfg loom` builds
//!    swap in the model-checked primitives.
//! 3. **`Ordering::Relaxed` is allowlisted per file.** Relaxed is correct
//!    only for pure counters; each allowlisted file carries a
//!    "Relaxed (allowlisted counter)" rationale comment, and any new use
//!    must be argued into [`RELAXED_ALLOWLIST`].
//! 4. **No `.unwrap()` / `.expect(` on serving paths.** Non-test code
//!    under `model/`, `coordinator/`, `server/` and `store/` must
//!    propagate or degrade, never panic — a panic there kills a worker
//!    thread or poisons shared state mid-protocol.
//! 5. **Spill IO goes through `store/spill.rs`.** Non-test code under
//!    `store/` may not name `std::fs::` outside the spill module: the
//!    atomic-publication / quarantine / failpoint discipline lives
//!    there, and a raw filesystem call next to it silently bypasses all
//!    three (crash-safety is a property of the whole tier, not of one
//!    call site).
//! 6. **No bare prints in library code.** `println!` / `eprintln!` in
//!    non-test library code outside [`PRINT_ALLOWLIST`] is banned: a
//!    stray print is invisible to the metrics registry and the flight
//!    recorder, and on the server it corrupts nothing but explains
//!    nothing either. Diagnostics go through `crate::telemetry`
//!    (counters, flight-recorder events); user-facing output lives in
//!    the CLI and the experiment harness.
//!
//! The linter is deliberately **lexical**: comments and string/char
//! literals are masked out first, then `#[cfg(test)]` item regions are
//! tracked by brace depth, then the rules run on what remains. No parser
//! dependency, no false positives from tokens inside strings or docs.

/// One rule violation at a specific line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Path relative to the linted root (e.g. `kernel/x86.rs`).
    pub file: String,
    /// 1-indexed line number.
    pub line: usize,
    /// Stable rule identifier (`unsafe-no-safety`, `stray-std-sync`,
    /// `relaxed-ordering`, `banned-unwrap`, `spill-direct-io`,
    /// `bare-print`).
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Files (by `/`-separated path relative to the linted root) allowed to
/// use `Ordering::Relaxed`. Every entry is a pure counter whose value
/// guards no other memory; see docs/concurrency.md for the argument.
pub const RELAXED_ALLOWLIST: &[&str] = &[
    // Work-claim / index-handout counters; claimed data is synchronized
    // by scope join (par_map) or channel send (router, coordinator).
    "util/parallel.rs",
    "coordinator/mod.rs",
    "coordinator/router.rs",
    // Monotonic statistics counters.
    "runtime/mod.rs",
    // Spill-dir uniqueness counter.
    "store/cache.rs",
    // The metrics registry itself: counters, gauges (f64-as-bits
    // store/load), and histogram buckets are all pure statistics whose
    // values guard no other memory; CAS loops for sum/max tolerate
    // Relaxed because each update is a single-word publication.
    "telemetry/mod.rs",
];

/// Path prefixes (relative to the linted root) where non-test
/// `.unwrap()` / `.expect(` are banned — serving-path directories plus
/// the head-policy module the engine calls on the decode path.
pub const NO_PANIC_DIRS: &[&str] = &["model/", "coordinator/", "server/", "store/", "policy.rs"];

/// The one file allowed to name `std::sync::atomic` / `std::sync::RwLock`.
pub const SYNC_FACADE: &str = "util/sync.rs";

/// The one file under `store/` allowed to name `std::fs::` — the
/// failpoint-instrumented spill-tier IO helpers (rule 5).
pub const SPILL_FACADE: &str = "store/spill.rs";

/// Path prefixes (relative to the linted root) where `println!` /
/// `eprintln!` are legitimate (rule 6): the CLI binary, the experiment
/// harness (paper tables go to stdout by design), the bench reporter,
/// and the telemetry layer itself — everywhere else diagnostics must go
/// through the metrics registry or the flight recorder.
pub const PRINT_ALLOWLIST: &[&str] = &["main.rs", "experiments/", "util/bench.rs", "telemetry/"];

/// Lint one file's source. `rel_path` is `/`-separated and relative to
/// the linted root (`rust/src`); the rules that key on location
/// (allowlists, banned dirs, the facade itself) match against it.
pub fn lint_source(rel_path: &str, src: &str) -> Vec<Violation> {
    let masked = mask(src);
    let masked_lines: Vec<&str> = masked.lines().collect();
    let raw_lines: Vec<&str> = src.lines().collect();
    let test_lines = test_region_lines(&masked_lines);

    let mut out = Vec::new();
    let is_facade = rel_path == SYNC_FACADE;
    let relaxed_ok = RELAXED_ALLOWLIST.contains(&rel_path);
    let no_panic = NO_PANIC_DIRS.iter().any(|d| rel_path.starts_with(d));
    let print_ok = PRINT_ALLOWLIST.iter().any(|d| rel_path.starts_with(d));

    for (i, line) in masked_lines.iter().enumerate() {
        let ln = i + 1;
        let in_test = test_lines.get(i).copied().unwrap_or(false);

        if contains_word(line, "unsafe") && !has_safety_adjacent(&masked_lines, &raw_lines, i) {
            out.push(Violation {
                file: rel_path.to_string(),
                line: ln,
                rule: "unsafe-no-safety",
                message: "`unsafe` without an adjacent `// SAFETY:` comment \
                          (or `/// # Safety` doc section for an unsafe fn)"
                    .to_string(),
            });
        }

        if !is_facade && (line.contains("std::sync::atomic") || line.contains("std::sync::RwLock"))
        {
            out.push(Violation {
                file: rel_path.to_string(),
                line: ln,
                rule: "stray-std-sync",
                message: "use crate::util::sync instead of std::sync::atomic / \
                          std::sync::RwLock (loom facade rule)"
                    .to_string(),
            });
        }

        if !relaxed_ok && line.contains("Ordering::Relaxed") {
            out.push(Violation {
                file: rel_path.to_string(),
                line: ln,
                rule: "relaxed-ordering",
                message: "Ordering::Relaxed outside the allowlist; use Acquire/Release \
                          or argue this file into lint::RELAXED_ALLOWLIST"
                    .to_string(),
            });
        }

        if no_panic && !in_test {
            // `.expect_err(` never matches: the `(` must follow `expect`.
            if line.contains(".unwrap()") || line.contains(".expect(") {
                out.push(Violation {
                    file: rel_path.to_string(),
                    line: ln,
                    rule: "banned-unwrap",
                    message: "unwrap/expect on a serving path; propagate the error or \
                              degrade explicitly"
                        .to_string(),
                });
            }
        }

        if !print_ok && !in_test && (line.contains("println!") || line.contains("eprintln!")) {
            out.push(Violation {
                file: rel_path.to_string(),
                line: ln,
                rule: "bare-print",
                message: "bare println!/eprintln! in library code; use crate::telemetry \
                          (a counter or flight-recorder event) or argue this path into \
                          lint::PRINT_ALLOWLIST"
                    .to_string(),
            });
        }

        if rel_path.starts_with("store/")
            && rel_path != SPILL_FACADE
            && !in_test
            && line.contains("std::fs::")
        {
            out.push(Violation {
                file: rel_path.to_string(),
                line: ln,
                rule: "spill-direct-io",
                message: "raw std::fs:: under store/; route spill-tier IO through \
                          store/spill.rs (atomic publish + quarantine + failpoints)"
                    .to_string(),
            });
        }
    }
    out
}

/// Replace the contents of comments and string/char literals with spaces,
/// preserving line structure, so token rules never fire inside them.
/// Handles line and nested block comments, escaped strings, raw (and
/// byte/raw-byte) strings, and distinguishes char literals from
/// lifetimes (`'a` / `'static` stay; `'x'`, `'\n'` are masked).
pub fn mask(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;

    // Push `count` chars starting at i as blanks (newlines preserved).
    let blank = |out: &mut String, b: &[char], from: usize, to: usize| {
        for &c in &b[from..to] {
            out.push(if c == '\n' { '\n' } else { ' ' });
        }
    };

    while i < n {
        let c = b[i];
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let mut j = i;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            blank(&mut out, &b, i, j);
            i = j;
            continue;
        }
        // Block comment (nested).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, &b, i, j);
            i = j;
            continue;
        }
        // Raw / byte / byte-raw strings: r"..", r#".."#, b".." , br#".."#.
        if c == 'r' || c == 'b' {
            let mut j = i;
            if b[j] == 'b' {
                j += 1;
            }
            let raw = j < n && b[j] == 'r';
            if raw {
                j += 1;
            }
            let mut hashes = 0usize;
            while raw && j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' && (raw || b[i] == 'b') {
                // Opening found: scan to the matching close.
                let mut k = j + 1;
                'scan: while k < n {
                    if b[k] == '\\' && !raw {
                        k += 2;
                        continue;
                    }
                    if b[k] == '"' {
                        let mut h = 0usize;
                        while h < hashes && k + 1 + h < n && b[k + 1 + h] == '#' {
                            h += 1;
                        }
                        if h == hashes {
                            k += 1 + hashes;
                            break 'scan;
                        }
                    }
                    k += 1;
                }
                blank(&mut out, &b, i, k.min(n));
                i = k.min(n);
                continue;
            }
            // Not a string prefix after all: fall through as plain chars.
        }
        // Plain string.
        if c == '"' {
            let mut j = i + 1;
            while j < n {
                if b[j] == '\\' {
                    j += 2;
                    continue;
                }
                if b[j] == '"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            blank(&mut out, &b, i, j.min(n));
            i = j.min(n);
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            // Escaped char: '\X...' up to the closing quote. Start past
            // the escaped character so '\'' terminates correctly.
            if i + 1 < n && b[i + 1] == '\\' {
                let mut j = i + 3;
                while j < n && b[j] != '\'' {
                    j += 1;
                }
                blank(&mut out, &b, i, (j + 1).min(n));
                i = (j + 1).min(n);
                continue;
            }
            // Simple char: 'x'.
            if i + 2 < n && b[i + 2] == '\'' {
                blank(&mut out, &b, i, i + 3);
                i += 3;
                continue;
            }
            // Lifetime: keep as-is.
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

/// Whether `needle` occurs in `line` as a standalone word (not part of a
/// longer identifier).
fn contains_word(line: &str, needle: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(pos) = line[from..].find(needle) {
        let start = from + pos;
        let end = start + needle.len();
        let pre_ok = start == 0 || !is_ident(bytes[start - 1]);
        let post_ok = end >= bytes.len() || !is_ident(bytes[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = end;
    }
    false
}

fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Whether line `idx` (0-based, containing an `unsafe` token) has a
/// justification: `SAFETY:` on the same line's comment, or in the
/// contiguous comment/attribute block directly above. `unsafe fn`
/// declarations additionally accept a `/// # Safety` doc heading there.
fn has_safety_adjacent(masked: &[&str], raw: &[&str], idx: usize) -> bool {
    let accepts_doc = {
        let m = masked[idx];
        contains_word(m, "fn") && contains_word(m, "unsafe")
    };
    if raw[idx].contains("SAFETY:") {
        return true;
    }
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let t = raw[i].trim_start();
        if t.starts_with("//") {
            if t.contains("SAFETY:") || (accepts_doc && t.contains("# Safety")) {
                return true;
            }
            continue;
        }
        // Attributes (and blank lines) between the comment and the item
        // don't break adjacency: `// SAFETY:` above `#[target_feature]`.
        if t.starts_with("#[") || t.starts_with("#![") || t.is_empty() {
            continue;
        }
        break;
    }
    false
}

/// Per-line flags: true when the line falls inside a `#[cfg(test)]` item
/// (tracked by brace depth on the masked source). Conservative in the
/// linter's favor: an un-braced `#[cfg(test)]` item extends to EOF.
fn test_region_lines(masked: &[&str]) -> Vec<bool> {
    let mut flags = vec![false; masked.len()];
    let mut i = 0;
    while i < masked.len() {
        if masked[i].contains("#[cfg(test)]") {
            let mut depth = 0i64;
            let mut started = false;
            let mut j = i;
            while j < masked.len() {
                for ch in masked[j].chars() {
                    match ch {
                        '{' => {
                            depth += 1;
                            started = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                if started && depth <= 0 {
                    break;
                }
                j += 1;
            }
            let end = j.min(masked.len() - 1);
            for f in flags.iter_mut().take(end + 1).skip(i) {
                *f = true;
            }
            i = end + 1;
        } else {
            i += 1;
        }
    }
    flags
}

/// Walk `root` recursively and lint every `.rs` file, returning all
/// violations sorted by (file, line). `root` is typically `rust/src`.
pub fn lint_tree(root: &std::path::Path) -> std::io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in files {
        let src = std::fs::read_to_string(&path)?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        out.extend(lint_source(&rel, &src));
    }
    Ok(out)
}

fn collect_rs(dir: &std::path::Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
