//! Repo task runner library. The only task so far is the invariant
//! linter (`cargo xtask lint`) — see [`lint`].

pub mod lint;
