//! Bench: index construction cost (the prefill-side price of each method).
//!
//! The paper's index build happens once per prompt during prefill (§3.2,
//! exact KNN on GPU + projection); this bench measures our host-side
//! build across index families and corpus sizes, plus the ablation of
//! RoarGraph's `kb` (bipartite degree) — a DESIGN.md §5 design choice.

use retrieval_attention::index::{
    hnsw::{HnswIndex, HnswParams}, ivf::IvfIndex, roargraph::{RoarGraph, RoarParams},
    VectorIndex,
};
use retrieval_attention::tensor::Matrix;
use retrieval_attention::util::bench::{black_box, Bencher};
use retrieval_attention::workload::geometry::{generate, GeometryParams};
use std::sync::Arc;

fn main() {
    let full = std::env::args().any(|a| a == "full");
    let sizes: &[usize] = if full { &[16_384, 65_536] } else { &[8_192, 16_384] };
    let mut b = if full { Bencher::default() } else { Bencher::quick() };
    b.max_iters = 5;

    for &n in sizes {
        let g = generate(&GeometryParams::default(), n, 1024, 7);
        let keys = Arc::new(g.keys);
        let train = Matrix::from_fn(512, 64, |r, c| g.queries[(r, c)]);

        b.bench(&format!("build/ivf/n={n}"), || {
            black_box(IvfIndex::build(keys.clone(), None, 1).nlist())
        });
        b.bench(&format!("build/hnsw/n={n}"), || {
            black_box(HnswIndex::build(keys.clone(), HnswParams::default()).len())
        });
        // Ablation: bipartite KNN degree kb (quality-vs-build-cost knob).
        for kb in [16usize, 32, 64] {
            b.bench(&format!("build/roargraph/kb={kb}/n={n}"), || {
                black_box(
                    RoarGraph::build(
                        keys.clone(),
                        &train,
                        RoarParams { kb, m: 32, repair_sample: 256, ..RoarParams::default() },
                    )
                    .avg_degree(),
                )
            });
        }
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_index_build.json", b.to_json().to_string_pretty()).ok();
}
