//! Bench: index search latency & scan fractions (backs Tables 4/5, Fig 6).
//!
//! `cargo bench --bench index_search [-- full]`

use retrieval_attention::index::{
    flat::FlatIndex, hnsw::{HnswIndex, HnswParams}, ivf::IvfIndex,
    roargraph::{RoarGraph, RoarParams}, SearchParams, VectorIndex,
};
use retrieval_attention::tensor::Matrix;
use retrieval_attention::util::bench::{black_box, Bencher};
use retrieval_attention::workload::geometry::{generate, GeometryParams};
use std::sync::Arc;

fn main() {
    let full = std::env::args().any(|a| a == "full");
    let sizes: &[usize] = if full { &[16_384, 65_536, 131_072] } else { &[16_384, 65_536] };
    let mut b = if full { Bencher::default() } else { Bencher::quick() };

    for &n in sizes {
        let g = generate(&GeometryParams::default(), n, 2048 + 64, 42);
        let keys = Arc::new(g.keys);
        let train = Matrix::from_fn(2048, 64, |r, c| g.queries[(64 + r, c)]);
        let queries: Vec<Vec<f32>> = (0..64).map(|i| g.queries.row(i).to_vec()).collect();

        let flat = FlatIndex::new(keys.clone());
        let ivf = IvfIndex::build(keys.clone(), None, 1);
        let hnsw = HnswIndex::build(keys.clone(), HnswParams::default());
        let roar = RoarGraph::build(keys.clone(), &train, RoarParams::default());

        let mut qi = 0usize;
        let mut run = |name: String, index: &dyn VectorIndex, p: SearchParams| {
            let mut scanned = 0usize;
            let mut count = 0usize;
            b.bench(&name, || {
                let q = &queries[qi % queries.len()];
                qi += 1;
                let r = index.search(q, 100, &p);
                scanned += r.scanned;
                count += 1;
                black_box(r.ids.len())
            });
            println!(
                "    -> mean scan fraction {:.2}%",
                100.0 * scanned as f64 / (count * n) as f64
            );
        };
        run(format!("flat/top100/n={n}"), &flat, SearchParams::default());
        run(format!("ivf/np32/n={n}"), &ivf, SearchParams { ef: 0, nprobe: 32 });
        run(format!("hnsw/ef128/n={n}"), &hnsw, SearchParams { ef: 128, nprobe: 0 });
        run(format!("roargraph/ef128/n={n}"), &roar, SearchParams { ef: 128, nprobe: 0 });
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_index_search.json", b.to_json().to_string_pretty()).ok();
}
