//! Bench: attention hot paths — host sparse attention, γ-combine, and the
//! device `static_attn` / `combine` artifacts (the L1 Pallas kernels as
//! compiled into the serving stack).
//!
//! Includes the on-device vs on-host combine ablation (DESIGN.md §5).

use retrieval_attention::attention::{attend_subset, combine, PartialAttention};
use retrieval_attention::runtime::{literal_f32, Runtime};
use retrieval_attention::util::bench::{black_box, Bencher};
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::geometry::{generate, GeometryParams};

fn main() {
    let full = std::env::args().any(|a| a == "full");
    let mut b = if full { Bencher::default() } else { Bencher::quick() };

    // Host sparse attention over a retrieved set (the Omega side).
    let g = generate(&GeometryParams::default(), 131_072, 8, 3);
    let q = g.queries.row(0).to_vec();
    for topk in [100usize, 500, 2000] {
        let ids: Vec<u32> = (0..topk as u32).map(|i| i * 61 % 131_072).collect();
        b.bench(&format!("host/attend_subset/k={topk}"), || {
            black_box(attend_subset(&q, &g.keys, &g.values, &ids, 0.125).lse)
        });
    }

    // Host gamma-combine.
    let mut rng = Rng::seed_from(5);
    let mk = |rng: &mut Rng| PartialAttention {
        o: (0..64).map(|_| rng.normal()).collect(),
        lse: rng.normal() * 3.0,
    };
    let p1 = mk(&mut rng);
    let p2 = mk(&mut rng);
    b.bench("host/combine/d=64", || black_box(combine(&[p1.clone(), p2.clone()]).lse));

    // Device entry points: compiled Pallas artifacts when `make artifacts`
    // has run, the runtime's native backend otherwise.
    {
        let rt = Runtime::load_auto("artifacts", "llama3-mini").expect("runtime");
        let backend = if rt.is_native() { "native" } else { "pallas" };
        eprintln!("device kernels backend: {}", rt.platform());
        let spec = rt.meta().spec.clone();
        let (s, kv, h, dh) = (spec.static_len, spec.kv_heads, spec.q_heads, spec.head_dim);
        let qs = literal_f32(&vec![0.1; h * dh], &[h as i64, dh as i64]).unwrap();
        let ks = literal_f32(&vec![0.2; s * kv * dh], &[s as i64, kv as i64, dh as i64]).unwrap();
        let vs = literal_f32(&vec![0.3; s * kv * dh], &[s as i64, kv as i64, dh as i64]).unwrap();
        let ms = literal_f32(&vec![0.0; s], &[s as i64]).unwrap();
        b.bench(&format!("device/static_attn({backend} flash_decode, S=640)"), || {
            black_box(rt.exec("static_attn", &[&qs, &ks, &vs, &ms]).unwrap().len())
        });

        let o1 = literal_f32(&vec![0.1; h * dh], &[h as i64, dh as i64]).unwrap();
        let l1 = literal_f32(&vec![1.0; h], &[h as i64]).unwrap();
        b.bench(&format!("device/combine({backend}) [ablation vs host/combine]"), || {
            black_box(rt.exec("combine", &[&o1, &l1, &o1, &l1]).unwrap().len())
        });
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_attention.json", b.to_json().to_string_pretty()).ok();
}
