//! Bench: end-to-end per-token decode latency by method and context
//! length — the measured backbone of Tables 4/7/8 — plus the online-
//! maintenance flatness check: per-token decode cost as the generated
//! length grows past `sink + window`, with the overflow→index drain
//! running on the background worker, inline (synchronous), or disabled.
//! With maintenance on, cost stays ~flat (the overflow buffer is bounded
//! by the watermark, and with the worker on, even the insert cost leaves
//! the token path); with it off, the linear overflow scan grows with
//! every generated token.
//!
//! Also profiles the drain's store-growth cost directly: segmented append
//! (`KeyStore::append_rows`, O(batch) amortised) vs the monolithic
//! deep-copy PR 1 used (O(context) per drain), at up to 128K-row
//! geometry in `full` mode — and the reclaim-on/off host-memory growth
//! contrast for the streaming-eviction regime (generation-based dense-id
//! remap epochs vs tombstones-only).
//!
//! `cargo bench --bench decode_latency [-- full]`
//!
//! Runs against PJRT artifacts when present, the native backend otherwise.

use retrieval_attention::config::{Method, ServeConfig};
use retrieval_attention::index::KeyStore;
use retrieval_attention::model::Engine;
use retrieval_attention::tensor::Matrix;
use retrieval_attention::util::bench::{black_box, Bencher};
use retrieval_attention::util::json::Value;
use retrieval_attention::workload::geometry::{generate, GeometryParams};

fn heads_for(
    spec: &retrieval_attention::runtime::manifest::SpecMeta,
    n: usize,
) -> Vec<Vec<retrieval_attention::workload::geometry::HeadGeometry>> {
    (0..spec.layers)
        .map(|l| {
            (0..spec.kv_heads)
                .map(|k| {
                    generate(
                        &GeometryParams { head_dim: spec.head_dim, ..Default::default() },
                        n,
                        512,
                        (l * 7 + k) as u64,
                    )
                })
                .collect()
        })
        .collect()
}

/// Decode `gen` tokens; return mean seconds/token over the first and last
/// `window` steps plus the session drain counters.
fn growth_profile(
    engine: &Engine,
    heads: Vec<Vec<retrieval_attention::workload::geometry::HeadGeometry>>,
    method: Method,
    gen: usize,
    window: usize,
) -> (f64, f64, u64, u64) {
    let mut sess = engine.synthetic_session(heads, method).expect("session");
    let mut per_token: Vec<f64> = Vec::with_capacity(gen);
    let mut tok = 1u32;
    for _ in 0..gen {
        let t = std::time::Instant::now();
        tok = black_box(engine.decode_step(&mut sess, tok % 97).unwrap().token);
        per_token.push(t.elapsed().as_secs_f64());
    }
    let w = window.min(per_token.len() / 2).max(1);
    let early: f64 = per_token[..w].iter().sum::<f64>() / w as f64;
    let late: f64 = per_token[per_token.len() - w..].iter().sum::<f64>() / w as f64;
    (early, late, sess.drained_tokens, sess.drains)
}

fn main() {
    let full = std::env::args().any(|a| a == "full");
    let lengths: &[usize] = if full { &[8_192, 32_768, 131_072] } else { &[4_096, 16_384] };
    let methods =
        [Method::StreamingLlm, Method::Flat, Method::Ivf, Method::RetrievalAttention];
    let mut b = if full { Bencher::default() } else { Bencher::quick() };
    b.max_iters = if full { 50 } else { 10 };

    let mut cfg = ServeConfig::default();
    cfg.model = "llama3-mini".into();
    let engine = Engine::from_config(cfg).expect("engine");
    let spec = engine.spec().clone();
    eprintln!("decode_latency: backend = {}", engine.rt.platform());

    for &n in lengths {
        let heads = heads_for(&spec, n);
        for &m in &methods {
            let mut sess = engine.synthetic_session(heads.clone(), m).expect("session");
            engine.decode_step(&mut sess, 1).unwrap(); // warmup
            let mut i = 0u32;
            b.bench(&format!("decode/{}/n={n}", m.label()), || {
                i += 1;
                black_box(engine.decode_step(&mut sess, i % 97).unwrap().token)
            });
        }
    }

    // --- Long-generation flatness: worker on / sync drain / drain off. ---
    let n = if full { 16_384 } else { 2_048 };
    let gen = if full { 1_024 } else { 384 };
    let probe = 64usize;
    let mut growth = Value::obj();
    for (tag, watermark, async_worker) in [
        ("worker-on", 32usize, true),
        ("worker-off-sync", 32usize, false),
        ("drain-off", 0usize, false),
    ] {
        let mut cfg = ServeConfig::default();
        cfg.model = "llama3-mini".into();
        cfg.retrieval.maintenance.drain_watermark = watermark;
        cfg.retrieval.maintenance.async_worker = async_worker;
        let engine = Engine::from_config(cfg).expect("engine");
        let heads = heads_for(&spec, n);
        let (early, late, drained, drains) =
            growth_profile(&engine, heads, Method::RetrievalAttention, gen, probe);
        let ratio = if early > 0.0 { late / early } else { 0.0 };
        println!(
            "growth/RetrievalAttention/{tag}: n={n} gen={gen} \
             early={:.3}ms late={:.3}ms late/early={:.2} drains={drains} drained={drained}",
            early * 1e3,
            late * 1e3,
            ratio,
        );
        let mut o = Value::obj();
        o.set("n", n)
            .set("generated", gen)
            .set("early_s_per_tok", early)
            .set("late_s_per_tok", late)
            .set("late_over_early", ratio)
            .set("drained_tokens", drained)
            .set("drains", drains);
        growth.set(tag, o);
    }

    // --- Reclamation: host-memory growth with eviction on, reclaim on/off.
    // Same streaming regime either way (StreamingLLM-style retirement over
    // the indexed tier); the only difference is whether tombstoned rows
    // are physically reclaimed by generation-based remap epochs. With
    // reclaim off, store/map/index bytes only ever grow; with it on they
    // stay bounded near the live tier.
    let n_r = if full { 8_192 } else { 2_048 };
    let gen_r = if full { 768 } else { 320 };
    let mut reclaim = Value::obj();
    for (tag, ratio) in [("reclaim-on", 0.25f32), ("reclaim-off", 0.0f32)] {
        let mut cfg = ServeConfig::default();
        cfg.model = "llama3-mini".into();
        cfg.retrieval.maintenance.drain_watermark = 32;
        cfg.retrieval.eviction.max_indexed = 512;
        cfg.retrieval.eviction.reclaim_ratio = ratio;
        let engine = Engine::from_config(cfg).expect("engine");
        let heads = heads_for(&spec, n_r);
        let mut sess =
            engine.synthetic_session(heads, Method::RetrievalAttention).expect("session");
        let bytes_start = sess.index_memory_bytes();
        let t = std::time::Instant::now();
        let mut tok = 1u32;
        for _ in 0..gen_r {
            tok = black_box(engine.decode_step(&mut sess, tok % 97).unwrap().token);
        }
        let decode_s = t.elapsed().as_secs_f64() / gen_r as f64;
        sess.shutdown_maintenance();
        let bytes_end = sess.index_memory_bytes();
        let store_rows = sess.host_store(0, 0).rows();
        let stats = sess.maint.stats;
        println!(
            "reclaim/{tag}: n={n_r} gen={gen_r} bytes_start={bytes_start} bytes_end={bytes_end} \
             store_rows={store_rows} evicted={} reclaims={} reclaimed_rows={} s_per_tok={:.5}",
            stats.evicted_tokens, stats.reclaims, stats.reclaimed_rows, decode_s,
        );
        let mut o = Value::obj();
        o.set("n", n_r)
            .set("generated", gen_r)
            .set("bytes_start", bytes_start)
            .set("bytes_end", bytes_end)
            .set("store_rows", store_rows)
            .set("evicted_tokens", stats.evicted_tokens)
            .set("reclaims", stats.reclaims)
            .set("reclaimed_rows", stats.reclaimed_rows)
            .set("s_per_tok", decode_s);
        reclaim.set(tag, o);
    }

    // --- Drain store-growth: segmented append vs monolithic deep copy. ---
    // The segmented store appends one O(batch) chunk per drain (amortised
    // tail merging); the PR-1 layout re-copied the whole dense prefix.
    // 128K x 64 geometry in full mode makes that contrast ~three orders of
    // magnitude per drain.
    let drain_n = if full { 131_072 } else { 16_384 };
    let batch = 32usize;
    let drains = 64usize;
    let dim = 64usize;
    let prefix = Matrix::from_fn(drain_n, dim, |r, c| ((r * 31 + c) % 97) as f32 * 0.01);
    let batch_rows = Matrix::from_fn(batch, dim, |r, c| ((r * 13 + c) % 89) as f32 * 0.02);

    let t = std::time::Instant::now();
    let mut seg = KeyStore::from_matrix(prefix.clone());
    for _ in 0..drains {
        seg = black_box(seg.append_rows(batch_rows.clone()));
    }
    let seg_s = t.elapsed().as_secs_f64() / drains as f64;

    let t = std::time::Instant::now();
    let mut mono = prefix;
    for _ in 0..drains {
        // The old drain: clone the whole dense store, push the batch.
        let mut grown = mono.clone();
        for r in 0..batch_rows.rows() {
            grown.push_row(batch_rows.row(r));
        }
        mono = black_box(grown);
    }
    let mono_s = t.elapsed().as_secs_f64() / drains as f64;
    assert_eq!(seg.rows(), mono.rows(), "profiles diverged");
    let speedup = if seg_s > 0.0 { mono_s / seg_s } else { 0.0 };
    println!(
        "drain-store/n={drain_n}: segmented={:.3}us/drain monolithic-copy={:.3}us/drain \
         speedup={speedup:.1}x segments={}",
        seg_s * 1e6,
        mono_s * 1e6,
        seg.segment_count(),
    );
    let mut drain_profile = Value::obj();
    drain_profile
        .set("n", drain_n)
        .set("batch", batch)
        .set("drains", drains)
        .set("segmented_s_per_drain", seg_s)
        .set("monolithic_copy_s_per_drain", mono_s)
        .set("speedup", speedup)
        .set("segments", seg.segment_count());

    std::fs::create_dir_all("results").ok();
    let mut out = Value::obj();
    out.set("cases", b.to_json());
    out.set("growth", growth);
    out.set("reclaim", reclaim);
    out.set("drain_store", drain_profile);
    std::fs::write("results/bench_decode.json", out.to_string_pretty()).ok();
}
