//! Bench: end-to-end per-token decode latency by method and context
//! length — the measured backbone of Tables 4/7/8 — plus the online-
//! maintenance flatness check: per-token decode cost as the generated
//! length grows past `sink + window`, with the overflow→index drain
//! running on the background worker, inline (synchronous), or disabled.
//! With maintenance on, cost stays ~flat (the overflow buffer is bounded
//! by the watermark, and with the worker on, even the insert cost leaves
//! the token path); with it off, the linear overflow scan grows with
//! every generated token.
//!
//! Also profiles the drain's store-growth cost directly: segmented append
//! (`KeyStore::append_rows`, O(batch) amortised) vs the monolithic
//! deep-copy PR 1 used (O(context) per drain), at up to 128K-row
//! geometry in `full` mode — and the reclaim-on/off host-memory growth
//! contrast for the streaming-eviction regime (generation-based dense-id
//! remap epochs vs tombstones-only).
//!
//! New with the continuous-batching scheduler: a multi-session throughput
//! profile (1/4/16 resident sessions driven through `Engine::decode_wave`)
//! reporting tokens/sec/replica and p50 inter-token latency, recorded
//! under `multi_session` in the `BENCH_decode.json` summary.
//!
//! New with the per-head policy layer: a `head_policy` profile (policy
//! off vs calibrated-with-streaming-floor at 64K/128K in full mode)
//! contrasting per-head index bytes, snapshot bytes, maintenance CPU,
//! and decode throughput — the DuoAttention-style memory the streaming
//! tier gives back.
//!
//! `cargo bench --bench decode_latency [-- full]`
//!
//! Runs against PJRT artifacts when present, the native backend otherwise.

use retrieval_attention::config::{Method, ServeConfig};
use retrieval_attention::index::{
    exact_topk, flat::FlatIndex, roargraph::{RoarGraph, RoarParams}, search_rerank, KeyStore,
    SearchParams, VectorIndex,
};
use retrieval_attention::kernel::{self, QuantMode};
use retrieval_attention::model::Engine;
use retrieval_attention::telemetry;
use retrieval_attention::tensor::Matrix;
use retrieval_attention::util::bench::{black_box, Bencher};
use retrieval_attention::util::json::{self, Value};
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::geometry::{generate, GeometryParams};

/// Allocation-counting global allocator: wraps the system allocator and
/// counts every `alloc` call, so the smoke profile can assert the
/// disabled-telemetry hot path performs literally zero allocations.
struct CountingAlloc;

static ALLOC_CALLS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

// SAFETY: defers every operation to the system allocator unchanged; the
// counter is a side effect that never touches the returned memory.
unsafe impl std::alloc::GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::alloc::System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
        std::alloc::System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: std::alloc::Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::alloc::System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn heads_for(
    spec: &retrieval_attention::runtime::manifest::SpecMeta,
    n: usize,
) -> Vec<Vec<retrieval_attention::workload::geometry::HeadGeometry>> {
    (0..spec.layers)
        .map(|l| {
            (0..spec.kv_heads)
                .map(|k| {
                    generate(
                        &GeometryParams { head_dim: spec.head_dim, ..Default::default() },
                        n,
                        512,
                        (l * 7 + k) as u64,
                    )
                })
                .collect()
        })
        .collect()
}

/// Decode `gen` tokens; return mean seconds/token over the first and last
/// `window` steps plus the session drain counters.
fn growth_profile(
    engine: &Engine,
    heads: Vec<Vec<retrieval_attention::workload::geometry::HeadGeometry>>,
    method: Method,
    gen: usize,
    window: usize,
) -> (f64, f64, u64, u64) {
    let mut sess = engine.synthetic_session(heads, method).expect("session");
    let mut per_token: Vec<f64> = Vec::with_capacity(gen);
    let mut tok = 1u32;
    for _ in 0..gen {
        let t = std::time::Instant::now();
        tok = black_box(engine.decode_step(&mut sess, tok % 97).unwrap().token);
        per_token.push(t.elapsed().as_secs_f64());
    }
    let w = window.min(per_token.len() / 2).max(1);
    let early: f64 = per_token[..w].iter().sum::<f64>() / w as f64;
    let late: f64 = per_token[per_token.len() - w..].iter().sum::<f64>() / w as f64;
    (early, late, sess.drained_tokens, sess.drains)
}

/// Continuous-batching throughput: `residents` synthetic sessions decoded
/// together through `Engine::decode_wave` — the replica worker's fused
/// step — bypassing the channel/scheduler layer so the numbers isolate
/// the wave fusion itself from thread-scheduling noise. Each wave emits
/// one token per resident, so a wave's duration IS every resident's
/// inter-token latency, and tokens/sec/replica is residents × waves over
/// the measured wall time.
fn multi_session_profile(engine: &Engine, residents: &[usize], n: usize, waves: usize) -> Value {
    use retrieval_attention::model::WaveItem;
    let spec = engine.spec().clone();
    let mut cases: Vec<Value> = Vec::new();
    for &r in residents {
        let mut sessions: Vec<_> = (0..r)
            .map(|_| {
                engine
                    .synthetic_session(heads_for(&spec, n), Method::RetrievalAttention)
                    .expect("session")
            })
            .collect();
        let mut toks: Vec<u32> = (1..=r as u32).collect();
        let mut wave_s: Vec<f64> = Vec::with_capacity(waves);
        // Wave 0 is warmup (first-touch allocation, index warm paths).
        for w in 0..=waves {
            let mut items: Vec<WaveItem> = sessions
                .iter_mut()
                .zip(toks.iter())
                .map(|(sess, &token)| WaveItem { sess, token })
                .collect();
            let t = std::time::Instant::now();
            let outs = engine.decode_wave(&mut items);
            let dt = t.elapsed().as_secs_f64();
            drop(items);
            for (tok, out) in toks.iter_mut().zip(outs) {
                *tok = black_box(out.expect("wave decode").token % 97);
            }
            if w > 0 {
                wave_s.push(dt);
            }
        }
        for sess in &mut sessions {
            sess.shutdown_maintenance();
        }
        let wall: f64 = wave_s.iter().sum();
        let mut sorted = wave_s.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let p50 = sorted[sorted.len() / 2];
        let tokens = (r * waves) as f64;
        let tps = if wall > 0.0 { tokens / wall } else { 0.0 };
        println!(
            "multi-session/residents={r}: n={n} waves={waves} \
             tokens/s/replica={tps:.1} p50-inter-token={:.3}ms",
            p50 * 1e3,
        );
        let mut o = Value::obj();
        o.set("residents", r)
            .set("n", n)
            .set("waves", waves)
            .set("tokens_per_s_replica", tps)
            .set("p50_inter_token_s", p50);
        cases.push(o);
    }
    Value::Arr(cases)
}

/// The search-phase profile of the tentpole: quantized scan tier
/// (off/fp16/int8) × exact re-rank (on/off) per index family, with
/// recall@k against exact f32 ground truth. This is the measured point
/// the `BENCH_decode.json` perf trajectory records.
fn search_phase(b: &mut Bencher, flat_rows: &[usize], graph_rows: &[usize]) -> Value {
    let d = 64usize;
    let k = 100usize;
    let nq = 16usize;
    let mut cases: Vec<Value> = Vec::new();
    // (family tag, rows list); RoarGraph exercises the graph-gather path,
    // Flat the contiguous-scan path (the clearest bandwidth story).
    let families: [(&str, &[usize]); 2] = [("flat", flat_rows), ("roargraph", graph_rows)];
    for (family, lengths) in families {
        for &n in lengths {
            let mut rng = Rng::seed_from(0xC0FFEE ^ n as u64);
            let keys = Matrix::from_fn(n, d, |_, _| rng.normal());
            // OOD-ish queries, as the paper's decode distribution.
            let queries: Vec<Vec<f32>> = (0..nq)
                .map(|_| {
                    (0..d)
                        .map(|c| rng.normal() + if c < d / 4 { 1.0 } else { 0.0 })
                        .collect()
                })
                .collect();
            let train =
                Matrix::from_fn(256, d, |_, c| rng.normal() + if c < d / 4 { 1.0 } else { 0.0 });
            let truth: Vec<Vec<u32>> = queries.iter().map(|q| exact_topk(&keys, q, k)).collect();
            let mut baseline_p50 = 0.0f64;
            for mode in [QuantMode::Off, QuantMode::Fp16, QuantMode::Int8] {
                let store = KeyStore::from_matrix(keys.clone()).with_quant(mode);
                let idx: Box<dyn VectorIndex> = match family {
                    "flat" => Box::new(FlatIndex::new(store)),
                    _ => Box::new(RoarGraph::build(store, &train, RoarParams::default())),
                };
                let params = SearchParams { ef: 192, nprobe: 16 };
                for rerank in [0usize, 2] {
                    if rerank > 0 && mode == QuantMode::Off {
                        continue; // rerank is a no-op on the exact tier
                    }
                    let name = format!(
                        "search/{family}/n={n}/quant={}/rerank={rerank}",
                        mode.label()
                    );
                    let mut qi = 0usize;
                    let stats = b.bench(&name, || {
                        let q = &queries[qi % nq];
                        qi += 1;
                        black_box(search_rerank(idx.as_ref(), q, k, rerank, &params).ids.len())
                    });
                    let p50 = stats.p50.as_secs_f64();
                    let mean = stats.mean.as_secs_f64();
                    if mode == QuantMode::Off {
                        baseline_p50 = p50;
                    }
                    let mut recall = 0.0f32;
                    for (q, t) in queries.iter().zip(truth.iter()) {
                        recall += search_rerank(idx.as_ref(), q, k, rerank, &params)
                            .recall_against(t);
                    }
                    recall /= nq as f32;
                    let mut o = Value::obj();
                    o.set("family", family)
                        .set("n", n)
                        .set("quant", mode.label())
                        .set("rerank", rerank)
                        .set("p50_s", p50)
                        .set("mean_s", mean)
                        .set("recall_at_k", recall as f64)
                        .set(
                            "speedup_vs_f32",
                            if p50 > 0.0 { baseline_p50 / p50 } else { 0.0 },
                        );
                    println!(
                        "  -> {name}: p50={:.3}ms recall@{k}={recall:.3} speedup_vs_f32={:.2}x",
                        p50 * 1e3,
                        if p50 > 0.0 { baseline_p50 / p50 } else { 0.0 },
                    );
                    cases.push(o);
                }
            }
        }
    }
    Value::Arr(cases)
}

/// Session snapshot/restore profile: latency + bytes-on-disk of a full
/// session image vs the cost the restore avoids. The comparator measured
/// here is the session (re)build — retriever/index construction over the
/// same geometry, which is the floor of what a prefill-from-scratch pays
/// (a true re-prefill adds the model forward on top, so the reported
/// speedup is a LOWER bound on what the session cache saves per turn).
fn session_snapshot_profile(engine: &Engine, lengths: &[usize]) -> Value {
    let spec = engine.spec().clone();
    let mut cases: Vec<Value> = Vec::new();
    std::fs::create_dir_all("results").ok();
    let path = std::path::Path::new("results/session_snapshot.ras");
    for &n in lengths {
        let heads = heads_for(&spec, n);
        let t = std::time::Instant::now();
        let mut sess =
            engine.synthetic_session(heads, Method::RetrievalAttention).expect("session");
        let build_s = t.elapsed().as_secs_f64();

        let t = std::time::Instant::now();
        let file = std::fs::File::create(path).expect("spill file");
        let mut w = std::io::BufWriter::new(file);
        let bytes = engine.snapshot_session(&mut sess, &mut w).expect("snapshot");
        std::io::Write::flush(&mut w).expect("flush");
        drop(w);
        let snapshot_s = t.elapsed().as_secs_f64();

        let t = std::time::Instant::now();
        let file = std::fs::File::open(path).expect("reopen spill file");
        let mut r = std::io::BufReader::new(file);
        let restored = engine.restore_session(&mut r).expect("restore");
        let restore_s = t.elapsed().as_secs_f64();
        std::fs::remove_file(path).ok();
        assert_eq!(restored.len, sess.len, "restore diverged");
        assert_eq!(restored.maint.stats.swaps, 0, "restore did index work");

        let speedup = if restore_s > 0.0 { build_s / restore_s } else { 0.0 };
        println!(
            "session-snapshot/n={n}: build={build_s:.3}s snapshot={snapshot_s:.3}s \
             restore={restore_s:.3}s bytes={bytes} restore-vs-rebuild={speedup:.1}x"
        );
        let mut o = Value::obj();
        o.set("n", n)
            .set("build_s", build_s)
            .set("snapshot_s", snapshot_s)
            .set("restore_s", restore_s)
            .set("bytes_on_disk", bytes)
            .set("restore_speedup_vs_rebuild", speedup);
        cases.push(o);
    }
    Value::Arr(cases)
}

/// Head-policy profile: policy off vs a calibrated run whose override
/// floor pins the first half of every layer's query heads to the
/// streaming tier (synthetic geometry gives no natural span-mass signal,
/// so the floor makes the specialization deterministic; whatever the
/// live calibration pass decides on top only raises the fraction). Per
/// config: per-head index bytes, session snapshot bytes, maintenance
/// CPU, and decode throughput — the memory/CPU the streaming tier
/// returns and what it costs on the token path.
fn head_policy_profile(
    spec: &retrieval_attention::runtime::manifest::SpecMeta,
    lengths: &[usize],
    gen: usize,
) -> Value {
    use retrieval_attention::baselines::HostRetriever;
    use retrieval_attention::policy::PolicyMode;
    let mut cases: Vec<Value> = Vec::new();
    for &n in lengths {
        let mut row = Value::obj();
        row.set("n", n).set("generated", gen);
        let mut off_head_bytes = 0u64;
        let mut off_snap_bytes = 0u64;
        for tag in ["off", "calibrated"] {
            let mut cfg = ServeConfig::default();
            cfg.model = "llama3-mini".into();
            cfg.retrieval.maintenance.drain_watermark = 32;
            // Inline maintenance: swap_s_total then IS the maintenance
            // CPU this config spends, not a worker-thread overlap.
            cfg.retrieval.maintenance.async_worker = false;
            if tag == "calibrated" {
                cfg.policy.mode = PolicyMode::Calibrated;
                cfg.policy.calibration_steps = 8;
                cfg.policy.force_streaming = (0..spec.layers)
                    .flat_map(|l| (0..spec.q_heads / 2).map(move |h| (l, h)))
                    .collect();
            }
            let engine = Engine::from_config(cfg).expect("engine");
            let heads = heads_for(spec, n);
            let mut sess = engine
                .synthetic_session(heads, Method::RetrievalAttention)
                .expect("session");
            let t = std::time::Instant::now();
            let mut tok = 1u32;
            for _ in 0..gen {
                tok = black_box(engine.decode_step(&mut sess, tok % 97).unwrap().token);
            }
            let wall = t.elapsed().as_secs_f64();
            sess.shutdown_maintenance();
            let head_bytes: u64 =
                sess.retrievers.iter().flatten().map(|r| r.memory_bytes() as u64).sum();
            let snap_bytes = engine
                .snapshot_session(&mut sess, &mut std::io::sink())
                .expect("snapshot");
            let frac = sess.streaming_fraction();
            let tps = if wall > 0.0 { gen as f64 / wall } else { 0.0 };
            println!(
                "head-policy/{tag}: n={n} gen={gen} streaming_frac={frac:.2} \
                 head_index_bytes={head_bytes} snapshot_bytes={snap_bytes} \
                 maint_cpu_s={:.4} tokens/s={tps:.1}",
                sess.maint.stats.swap_s_total,
            );
            let mut o = Value::obj();
            o.set("streaming_fraction", frac)
                .set("head_index_bytes", head_bytes)
                .set("snapshot_bytes", snap_bytes)
                .set("index_bytes_avoided", sess.index_bytes_avoided)
                .set("maint_cpu_s", sess.maint.stats.swap_s_total)
                .set("tokens_per_s", tps);
            if tag == "off" {
                off_head_bytes = head_bytes;
                off_snap_bytes = snap_bytes;
            } else {
                let saved = |off: u64, now: u64| {
                    if off > 0 { (off - off.min(now)) as f64 / off as f64 } else { 0.0 }
                };
                row.set("head_index_bytes_saved_frac", saved(off_head_bytes, head_bytes));
                row.set("snapshot_bytes_saved_frac", saved(off_snap_bytes, snap_bytes));
            }
            row.set(tag, o);
        }
        cases.push(row);
    }
    Value::Arr(cases)
}

/// Write the repo-root perf-trajectory summary (phase medians + recall).
fn write_bench_summary(
    profile: &str,
    search: Value,
    decode_cases: Option<Value>,
    session_snapshot: Option<Value>,
    multi_session: Option<Value>,
    head_policy: Option<Value>,
) {
    let mut out = Value::obj();
    out.set("profile", profile)
        .set("kernel", kernel::active().label())
        .set("search_phase", search);
    if let Some(cases) = decode_cases {
        out.set("decode_cases", cases);
    }
    if let Some(snap) = session_snapshot {
        out.set("session_snapshot", snap);
    }
    if let Some(ms) = multi_session {
        out.set("multi_session", ms);
    }
    if let Some(hp) = head_policy {
        out.set("head_policy", hp);
    }
    // The process-wide metric registry rides along with every bench run:
    // the trajectory file records what the instrumented layers actually
    // counted, not just what the harness timed.
    out.set("telemetry_registry", telemetry::registry().snapshot());
    std::fs::write("BENCH_decode.json", out.to_string_pretty()).ok();
}

/// `bench-smoke`: tiny-geometry run asserting the JSON summary is
/// produced and the kernel dispatch actually selected a backend.
/// Assert the disabled-telemetry hot path allocates nothing: counter,
/// gauge, and histogram updates plus a gated span_record must be pure
/// atomic arithmetic. Runs first in smoke(), while the process is still
/// single-threaded, so the global allocation counter can't pick up noise
/// from worker threads.
fn assert_disabled_telemetry_path_is_allocation_free() {
    let reg = telemetry::registry();
    // Handle registration allocates; fetch everything before the window.
    let c = reg.counter("bench.smoke.counter");
    let g = reg.gauge("bench.smoke.gauge");
    let h = reg.histogram("bench.smoke.hist");
    let mut acc = telemetry::SpanAcc::default();
    let before = ALLOC_CALLS.load(std::sync::atomic::Ordering::Relaxed);
    for i in 0..10_000u64 {
        c.inc();
        g.set(i as f64);
        h.record(i as f64 * 1e-6);
        let t = telemetry::Stopwatch::start();
        telemetry::span_record(&mut acc, telemetry::Phase::Qkv, t.started(), t.elapsed_s(), 0);
    }
    let after = ALLOC_CALLS.load(std::sync::atomic::Ordering::Relaxed);
    assert!(acc.is_empty(), "spans must be disabled in the bench process");
    assert_eq!(
        after - before,
        0,
        "disabled-telemetry hot path allocated {} time(s) over 10k iterations",
        after - before
    );
    println!("bench-smoke: disabled-telemetry path performed 0 allocations over 10k ops");
}

fn smoke() {
    assert_disabled_telemetry_path_is_allocation_free();
    println!("bench-smoke: kernel dispatch = {}", kernel::active().label());
    #[cfg(target_arch = "x86_64")]
    {
        let forced = std::env::var("RA_KERNEL")
            .map(|v| v.eq_ignore_ascii_case("scalar"))
            .unwrap_or(false);
        if !forced && is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            assert_eq!(
                kernel::active(),
                kernel::Dispatch::Avx2,
                "AVX2+FMA present but dispatch fell back to {:?}",
                kernel::active()
            );
        }
    }
    let mut b = Bencher::quick();
    b.max_iters = 8;
    let search = search_phase(&mut b, &[2_048], &[1_024]);
    // Tiny-geometry snapshot/restore round trip: the persistence gate.
    let mut cfg = ServeConfig::default();
    cfg.model = "llama3-mini".into();
    let engine = Engine::from_config(cfg).expect("engine");
    let snap = session_snapshot_profile(&engine, &[1_024]);
    // Tiny continuous-batching profile: the wave entry point must produce
    // throughput numbers even at smoke geometry.
    let ms = multi_session_profile(&engine, &[1, 2], 512, 3);
    // Tiny head-policy contrast: the calibrated config must show its
    // streaming floor and give back per-head index + snapshot bytes.
    let hp = head_policy_profile(engine.spec(), &[1_024], 12);
    write_bench_summary("smoke", search, None, Some(snap), Some(ms), Some(hp));
    let text = std::fs::read_to_string("BENCH_decode.json").expect("BENCH_decode.json missing");
    let v = json::parse(&text).expect("BENCH_decode.json must parse");
    let cases = v.get("search_phase").and_then(Value::as_arr).expect("search_phase array");
    assert!(!cases.is_empty(), "no search-phase cases recorded");
    for c in cases {
        let recall = c.get("recall_at_k").and_then(Value::as_f64).expect("recall field");
        assert!(recall > 0.5, "implausible recall in smoke case: {recall}");
    }
    let snaps = v.get("session_snapshot").and_then(Value::as_arr).expect("session_snapshot");
    for c in snaps {
        let bytes = c.get("bytes_on_disk").and_then(Value::as_f64).expect("bytes field");
        assert!(bytes > 0.0, "empty session snapshot in smoke profile");
    }
    let ms = v.get("multi_session").and_then(Value::as_arr).expect("multi_session array");
    assert!(!ms.is_empty(), "no multi-session cases recorded");
    for c in ms {
        let tps = c.get("tokens_per_s_replica").and_then(Value::as_f64).expect("throughput field");
        assert!(tps > 0.0, "implausible multi-session throughput: {tps}");
        let p50 = c.get("p50_inter_token_s").and_then(Value::as_f64).expect("p50 field");
        assert!(p50 > 0.0, "implausible inter-token p50: {p50}");
    }
    let hp = v.get("head_policy").and_then(Value::as_arr).expect("head_policy array");
    assert!(!hp.is_empty(), "no head-policy cases recorded");
    for c in hp {
        let cal = c.get("calibrated").expect("calibrated config");
        let frac =
            cal.get("streaming_fraction").and_then(Value::as_f64).expect("fraction field");
        assert!(frac >= 0.25, "streaming floor not reached: {frac}");
        let head_saved = c
            .get("head_index_bytes_saved_frac")
            .and_then(Value::as_f64)
            .expect("head savings field");
        // Per-head index bytes scale with the head count, so the
        // streaming fraction is (within slack) a floor on the savings.
        assert!(
            head_saved >= frac * 0.8,
            "streaming {frac:.2} of heads saved only {head_saved:.2} of index bytes"
        );
        let snap_saved = c
            .get("snapshot_bytes_saved_frac")
            .and_then(Value::as_f64)
            .expect("snapshot savings field");
        assert!(snap_saved > 0.0, "streaming heads did not shrink the snapshot");
    }
    // The registry snapshot rides along: the wave profile above decoded
    // tokens, so the engine counters must be present and non-zero.
    let treg = v.get("telemetry_registry").expect("telemetry_registry in summary");
    let tokens = treg
        .get("counters")
        .and_then(|c| c.get("engine.tokens_total"))
        .and_then(Value::as_u64)
        .expect("engine.tokens_total counter");
    assert!(tokens > 0, "decode profiles ran but engine.tokens_total is 0");
    assert!(
        treg.get("histograms").and_then(|h| h.get("store.snapshot_s")).is_some(),
        "snapshot profile ran but store.snapshot_s histogram missing"
    );
    println!(
        "bench-smoke: OK ({} search-phase cases, kernel = {})",
        cases.len(),
        v.get("kernel").and_then(Value::as_str).unwrap_or("?")
    );
}

fn main() {
    if std::env::args().any(|a| a == "smoke") {
        smoke();
        return;
    }
    let full = std::env::args().any(|a| a == "full");
    let lengths: &[usize] = if full { &[8_192, 32_768, 131_072] } else { &[4_096, 16_384] };
    let methods =
        [Method::StreamingLlm, Method::Flat, Method::Ivf, Method::RetrievalAttention];
    let mut b = if full { Bencher::default() } else { Bencher::quick() };
    b.max_iters = if full { 50 } else { 10 };

    let mut cfg = ServeConfig::default();
    cfg.model = "llama3-mini".into();
    let engine = Engine::from_config(cfg).expect("engine");
    let spec = engine.spec().clone();
    eprintln!("decode_latency: backend = {}", engine.rt.platform());

    for &n in lengths {
        let heads = heads_for(&spec, n);
        for &m in &methods {
            let mut sess = engine.synthetic_session(heads.clone(), m).expect("session");
            engine.decode_step(&mut sess, 1).unwrap(); // warmup
            let mut i = 0u32;
            b.bench(&format!("decode/{}/n={n}", m.label()), || {
                i += 1;
                black_box(engine.decode_step(&mut sess, i % 97).unwrap().token)
            });
        }
    }

    // --- Search-phase profile: quant off/fp16/int8 × rerank on/off. ---
    // 64K rows always (the recorded trajectory point); 128K rows and the
    // 64K graph build in full mode.
    let (flat_rows, graph_rows): (&[usize], &[usize]) =
        if full { (&[65_536, 131_072], &[65_536]) } else { (&[65_536], &[16_384]) };
    let search = search_phase(&mut b, flat_rows, graph_rows);

    // --- Session snapshot/restore: latency + bytes-on-disk vs the
    // session-rebuild cost a `continue` turn avoids (64K/128K in full). ---
    let snap_lengths: &[usize] = if full { &[65_536, 131_072] } else { &[16_384] };
    let session_snapshot = session_snapshot_profile(&engine, snap_lengths);

    // --- Continuous batching: tokens/sec/replica and p50 inter-token
    // latency at 1/4/16 resident sessions through the fused wave step. ---
    let ms_n = if full { 8_192 } else { 2_048 };
    let ms_waves = if full { 32 } else { 12 };
    let multi_session = multi_session_profile(&engine, &[1, 4, 16], ms_n, ms_waves);

    // --- Head policy: off vs calibrated (64K/128K in full) — the index
    // bytes, maintenance CPU, and throughput the streaming tier trades. ---
    let hp_lengths: &[usize] = if full { &[65_536, 131_072] } else { &[16_384] };
    let hp_gen = if full { 64 } else { 32 };
    let head_policy = head_policy_profile(&spec, hp_lengths, hp_gen);

    // --- Long-generation flatness: worker on / sync drain / drain off. ---
    let n = if full { 16_384 } else { 2_048 };
    let gen = if full { 1_024 } else { 384 };
    let probe = 64usize;
    let mut growth = Value::obj();
    for (tag, watermark, async_worker) in [
        ("worker-on", 32usize, true),
        ("worker-off-sync", 32usize, false),
        ("drain-off", 0usize, false),
    ] {
        let mut cfg = ServeConfig::default();
        cfg.model = "llama3-mini".into();
        cfg.retrieval.maintenance.drain_watermark = watermark;
        cfg.retrieval.maintenance.async_worker = async_worker;
        let engine = Engine::from_config(cfg).expect("engine");
        let heads = heads_for(&spec, n);
        let (early, late, drained, drains) =
            growth_profile(&engine, heads, Method::RetrievalAttention, gen, probe);
        let ratio = if early > 0.0 { late / early } else { 0.0 };
        println!(
            "growth/RetrievalAttention/{tag}: n={n} gen={gen} \
             early={:.3}ms late={:.3}ms late/early={:.2} drains={drains} drained={drained}",
            early * 1e3,
            late * 1e3,
            ratio,
        );
        let mut o = Value::obj();
        o.set("n", n)
            .set("generated", gen)
            .set("early_s_per_tok", early)
            .set("late_s_per_tok", late)
            .set("late_over_early", ratio)
            .set("drained_tokens", drained)
            .set("drains", drains);
        growth.set(tag, o);
    }

    // --- Reclamation: host-memory growth with eviction on, reclaim on/off.
    // Same streaming regime either way (StreamingLLM-style retirement over
    // the indexed tier); the only difference is whether tombstoned rows
    // are physically reclaimed by generation-based remap epochs. With
    // reclaim off, store/map/index bytes only ever grow; with it on they
    // stay bounded near the live tier.
    let n_r = if full { 8_192 } else { 2_048 };
    let gen_r = if full { 768 } else { 320 };
    let mut reclaim = Value::obj();
    for (tag, ratio) in [("reclaim-on", 0.25f32), ("reclaim-off", 0.0f32)] {
        let mut cfg = ServeConfig::default();
        cfg.model = "llama3-mini".into();
        cfg.retrieval.maintenance.drain_watermark = 32;
        cfg.retrieval.eviction.max_indexed = 512;
        cfg.retrieval.eviction.reclaim_ratio = ratio;
        let engine = Engine::from_config(cfg).expect("engine");
        let heads = heads_for(&spec, n_r);
        let mut sess =
            engine.synthetic_session(heads, Method::RetrievalAttention).expect("session");
        let bytes_start = sess.index_memory_bytes();
        let t = std::time::Instant::now();
        let mut tok = 1u32;
        for _ in 0..gen_r {
            tok = black_box(engine.decode_step(&mut sess, tok % 97).unwrap().token);
        }
        let decode_s = t.elapsed().as_secs_f64() / gen_r as f64;
        sess.shutdown_maintenance();
        let bytes_end = sess.index_memory_bytes();
        let store_rows = sess.host_store(0, 0).rows();
        let stats = sess.maint.stats;
        println!(
            "reclaim/{tag}: n={n_r} gen={gen_r} bytes_start={bytes_start} bytes_end={bytes_end} \
             store_rows={store_rows} evicted={} reclaims={} reclaimed_rows={} s_per_tok={:.5}",
            stats.evicted_tokens, stats.reclaims, stats.reclaimed_rows, decode_s,
        );
        let mut o = Value::obj();
        o.set("n", n_r)
            .set("generated", gen_r)
            .set("bytes_start", bytes_start)
            .set("bytes_end", bytes_end)
            .set("store_rows", store_rows)
            .set("evicted_tokens", stats.evicted_tokens)
            .set("reclaims", stats.reclaims)
            .set("reclaimed_rows", stats.reclaimed_rows)
            .set("s_per_tok", decode_s);
        reclaim.set(tag, o);
    }

    // --- Drain store-growth: segmented append vs monolithic deep copy. ---
    // The segmented store appends one O(batch) chunk per drain (amortised
    // tail merging); the PR-1 layout re-copied the whole dense prefix.
    // 128K x 64 geometry in full mode makes that contrast ~three orders of
    // magnitude per drain.
    let drain_n = if full { 131_072 } else { 16_384 };
    let batch = 32usize;
    let drains = 64usize;
    let dim = 64usize;
    let prefix = Matrix::from_fn(drain_n, dim, |r, c| ((r * 31 + c) % 97) as f32 * 0.01);
    let batch_rows = Matrix::from_fn(batch, dim, |r, c| ((r * 13 + c) % 89) as f32 * 0.02);

    let t = std::time::Instant::now();
    let mut seg = KeyStore::from_matrix(prefix.clone());
    for _ in 0..drains {
        seg = black_box(seg.append_rows(batch_rows.clone()));
    }
    let seg_s = t.elapsed().as_secs_f64() / drains as f64;

    let t = std::time::Instant::now();
    let mut mono = prefix;
    for _ in 0..drains {
        // The old drain: clone the whole dense store, push the batch.
        let mut grown = mono.clone();
        for r in 0..batch_rows.rows() {
            grown.push_row(batch_rows.row(r));
        }
        mono = black_box(grown);
    }
    let mono_s = t.elapsed().as_secs_f64() / drains as f64;
    assert_eq!(seg.rows(), mono.rows(), "profiles diverged");
    let speedup = if seg_s > 0.0 { mono_s / seg_s } else { 0.0 };
    println!(
        "drain-store/n={drain_n}: segmented={:.3}us/drain monolithic-copy={:.3}us/drain \
         speedup={speedup:.1}x segments={}",
        seg_s * 1e6,
        mono_s * 1e6,
        seg.segment_count(),
    );
    let mut drain_profile = Value::obj();
    drain_profile
        .set("n", drain_n)
        .set("batch", batch)
        .set("drains", drains)
        .set("segmented_s_per_drain", seg_s)
        .set("monolithic_copy_s_per_drain", mono_s)
        .set("speedup", speedup)
        .set("segments", seg.segment_count());

    std::fs::create_dir_all("results").ok();
    let mut out = Value::obj();
    out.set("cases", b.to_json());
    out.set("growth", growth);
    out.set("reclaim", reclaim);
    out.set("drain_store", drain_profile);
    std::fs::write("results/bench_decode.json", out.to_string_pretty()).ok();
    // Repo-root perf-trajectory summary (phase medians + recall).
    write_bench_summary(
        if full { "full" } else { "quick" },
        search,
        Some(b.to_json()),
        Some(session_snapshot),
        Some(multi_session),
        Some(head_policy),
    );
}
