//! Bench: end-to-end per-token decode latency by method and context
//! length — the measured backbone of Tables 4/7/8 — plus the online-
//! maintenance flatness check: per-token decode cost as the generated
//! length grows past `sink + window`, with the overflow→index drain on
//! vs off. With the drain on, cost stays ~flat (the overflow buffer is
//! bounded by the watermark); with it off, the linear overflow scan grows
//! with every generated token.
//!
//! `cargo bench --bench decode_latency [-- full]`
//!
//! Runs against PJRT artifacts when present, the native backend otherwise.

use retrieval_attention::config::{Method, ServeConfig};
use retrieval_attention::model::Engine;
use retrieval_attention::util::bench::{black_box, Bencher};
use retrieval_attention::util::json::Value;
use retrieval_attention::workload::geometry::{generate, GeometryParams};

fn heads_for(
    spec: &retrieval_attention::runtime::manifest::SpecMeta,
    n: usize,
) -> Vec<Vec<retrieval_attention::workload::geometry::HeadGeometry>> {
    (0..spec.layers)
        .map(|l| {
            (0..spec.kv_heads)
                .map(|k| {
                    generate(
                        &GeometryParams { head_dim: spec.head_dim, ..Default::default() },
                        n,
                        512,
                        (l * 7 + k) as u64,
                    )
                })
                .collect()
        })
        .collect()
}

/// Decode `gen` tokens; return mean seconds/token over the first and last
/// `window` steps plus the session drain counters.
fn growth_profile(
    engine: &Engine,
    heads: Vec<Vec<retrieval_attention::workload::geometry::HeadGeometry>>,
    method: Method,
    gen: usize,
    window: usize,
) -> (f64, f64, u64, u64) {
    let mut sess = engine.synthetic_session(heads, method).expect("session");
    let mut per_token: Vec<f64> = Vec::with_capacity(gen);
    let mut tok = 1u32;
    for _ in 0..gen {
        let t = std::time::Instant::now();
        tok = black_box(engine.decode_step(&mut sess, tok % 97).unwrap().token);
        per_token.push(t.elapsed().as_secs_f64());
    }
    let w = window.min(per_token.len() / 2).max(1);
    let early: f64 = per_token[..w].iter().sum::<f64>() / w as f64;
    let late: f64 = per_token[per_token.len() - w..].iter().sum::<f64>() / w as f64;
    (early, late, sess.drained_tokens, sess.drains)
}

fn main() {
    let full = std::env::args().any(|a| a == "full");
    let lengths: &[usize] = if full { &[8_192, 32_768, 131_072] } else { &[4_096, 16_384] };
    let methods =
        [Method::StreamingLlm, Method::Flat, Method::Ivf, Method::RetrievalAttention];
    let mut b = if full { Bencher::default() } else { Bencher::quick() };
    b.max_iters = if full { 50 } else { 10 };

    let mut cfg = ServeConfig::default();
    cfg.model = "llama3-mini".into();
    let engine = Engine::from_config(cfg).expect("engine");
    let spec = engine.spec().clone();
    eprintln!("decode_latency: backend = {}", engine.rt.platform());

    for &n in lengths {
        let heads = heads_for(&spec, n);
        for &m in &methods {
            let mut sess = engine.synthetic_session(heads.clone(), m).expect("session");
            engine.decode_step(&mut sess, 1).unwrap(); // warmup
            let mut i = 0u32;
            b.bench(&format!("decode/{}/n={n}", m.label()), || {
                i += 1;
                black_box(engine.decode_step(&mut sess, i % 97).unwrap().token)
            });
        }
    }

    // --- Long-generation flatness: drain on vs off. ---
    let n = if full { 16_384 } else { 2_048 };
    let gen = if full { 1_024 } else { 384 };
    let probe = 64usize;
    let mut growth = Value::obj();
    for (tag, watermark) in [("drain-on", 64usize), ("drain-off", 0usize)] {
        let mut cfg = ServeConfig::default();
        cfg.model = "llama3-mini".into();
        cfg.retrieval.maintenance.drain_watermark = watermark;
        let engine = Engine::from_config(cfg).expect("engine");
        let heads = heads_for(&spec, n);
        let (early, late, drained, drains) =
            growth_profile(&engine, heads, Method::RetrievalAttention, gen, probe);
        let ratio = if early > 0.0 { late / early } else { 0.0 };
        println!(
            "growth/RetrievalAttention/{tag}: n={n} gen={gen} \
             early={:.3}ms late={:.3}ms late/early={:.2} drains={drains} drained={drained}",
            early * 1e3,
            late * 1e3,
            ratio,
        );
        let mut o = Value::obj();
        o.set("n", n)
            .set("generated", gen)
            .set("early_s_per_tok", early)
            .set("late_s_per_tok", late)
            .set("late_over_early", ratio)
            .set("drained_tokens", drained)
            .set("drains", drains);
        growth.set(tag, o);
    }

    std::fs::create_dir_all("results").ok();
    let mut out = Value::obj();
    out.set("cases", b.to_json());
    out.set("growth", growth);
    std::fs::write("results/bench_decode.json", out.to_string_pretty()).ok();
}
