//! Bench: end-to-end per-token decode latency by method and context
//! length — the measured backbone of Tables 4/7/8.
//!
//! `cargo bench --bench decode_latency [-- full]`

use retrieval_attention::config::{Method, ServeConfig};
use retrieval_attention::model::Engine;
use retrieval_attention::util::bench::{black_box, Bencher};
use retrieval_attention::workload::geometry::{generate, GeometryParams};

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts/ missing; run `make artifacts` first");
        return;
    }
    let full = std::env::args().any(|a| a == "full");
    let lengths: &[usize] = if full { &[8_192, 32_768, 131_072] } else { &[4_096, 16_384] };
    let methods =
        [Method::StreamingLlm, Method::Flat, Method::Ivf, Method::RetrievalAttention];
    let mut b = if full { Bencher::default() } else { Bencher::quick() };
    b.max_iters = if full { 50 } else { 10 };

    let mut cfg = ServeConfig::default();
    cfg.model = "llama3-mini".into();
    let engine = Engine::from_config(cfg).expect("engine");
    let spec = engine.spec().clone();

    for &n in lengths {
        let heads: Vec<Vec<_>> = (0..spec.layers)
            .map(|l| {
                (0..spec.kv_heads)
                    .map(|k| {
                        generate(
                            &GeometryParams { head_dim: spec.head_dim, ..Default::default() },
                            n,
                            512,
                            (l * 7 + k) as u64,
                        )
                    })
                    .collect()
            })
            .collect();
        for &m in &methods {
            let mut sess = engine.synthetic_session(heads.clone(), m).expect("session");
            engine.decode_step(&mut sess, 1).unwrap(); // warmup
            let mut i = 0u32;
            b.bench(&format!("decode/{}/n={n}", m.label()), || {
                i += 1;
                black_box(engine.decode_step(&mut sess, i % 97).unwrap().token)
            });
        }
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_decode.json", b.to_json().to_string_pretty()).ok();
}
