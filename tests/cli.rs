//! CLI integration: the launcher binary's non-serving commands.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_retrieval-attention"))
}

#[test]
fn experiment_list_names_every_paper_artifact() {
    let out = bin().args(["experiment", "list"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for id in ["table1", "table2", "table4", "table5", "fig2", "fig3a", "fig6", "fig8"] {
        assert!(text.contains(id), "experiment list missing {id}");
    }
}

#[test]
fn info_reports_presets() {
    // Runs against the artifact manifest when present, the built-in
    // native-backend presets otherwise.
    let out = bin().args(["info"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("induction-mini"));
    assert!(text.contains("llama3-mini"));
    assert!(text.contains("d_head 64"), "geometry line missing: {text}");
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = bin().args(["frobnicate"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("commands:"), "usage not printed");
}

#[test]
fn generate_round_trip() {
    let out = bin()
        .args([
            "generate",
            "--prompt-task",
            "passkey",
            "--len",
            "512",
            "--method",
            "RetrievalAttention",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("grade: 100%"), "generation failed: {text}");
}

#[test]
fn generate_rejects_unknown_method() {
    let out = bin().args(["generate", "--method", "MagicAttention"]).output().unwrap();
    assert!(!out.status.success());
}
