//! Spill/resume soak: park/resume churn over many concurrent sessions.
//!
//! Every finished turn is forced to disk (`max_resident_bytes = 0`), so a
//! round-robin of N sessions × M turns exercises the full
//! active → resident → parked → resumed cycle N×M times, with the async
//! maintenance worker ON (snapshot-time flushes race real background
//! drains here). Run serialized (`--test-threads=1`) and timeout-guarded
//! in CI, like the maintenance-concurrency suite.

use retrieval_attention::config::{Method, ServeConfig};
use retrieval_attention::coordinator::{collect, Replica, Request, SessionMode, SessionSpec};
use retrieval_attention::kvcache::StaticPattern;
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::tasks;

#[test]
fn park_resume_churn_over_many_sessions() {
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = Method::RetrievalAttention;
    cfg.pattern = StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.ef = 64;
    // Low watermark so the later turns' decode-extends push overflow past
    // it: real background drains land between parks and resumes.
    cfg.retrieval.maintenance.drain_watermark = 8;
    cfg.serving.session_cache.max_resident_bytes = 0; // every turn parks
    let rep = Replica::spawn(cfg);

    const SESSIONS: u64 = 12;
    const TURNS: usize = 3;
    let mut rng = Rng::seed_from(5);
    let samples: Vec<_> = (0..SESSIONS).map(|_| tasks::passkey(&mut rng, 400, 0.3)).collect();

    let mut req_id = 0u64;
    let mut last_metrics = None;
    for turn in 0..TURNS {
        // Interleave: session 0's turn 2 only runs after every session's
        // turn 1 parked, so each resume really comes off disk.
        for (si, s) in samples.iter().enumerate() {
            req_id += 1;
            let (mode, prompt) = if turn == 0 {
                (SessionMode::Open, s.prompt.clone())
            } else {
                (SessionMode::Continue, vec![7 + turn as u32, 3, si as u32 % 5 + 1])
            };
            let rx = rep.submit(Request {
                id: req_id,
                prompt,
                max_tokens: 2,
                session: Some(SessionSpec { session_id: si as u64, mode }),
            });
            let (tokens, m) = collect(&rx).unwrap_or_else(|e| {
                panic!("session {si} turn {turn} failed: {e}");
            });
            assert_eq!(tokens.len(), 2, "session {si} turn {turn}");
            if turn == 0 {
                assert!(s.passed(&tokens), "session {si}: wrong first answer {tokens:?}");
                assert!(!m.resumed_from_disk);
            } else {
                assert!(m.resumed_from_disk, "session {si} turn {turn} should come off disk");
                assert!(m.snapshot_bytes > 0);
            }
            last_metrics = Some(m);
        }
    }
    let m = last_metrics.expect("ran turns");
    // Every turn parked and every turn >= 2 resumed.
    assert_eq!(m.session_parks, SESSIONS * TURNS as u64, "park churn miscounted");
    assert_eq!(m.session_resumes, SESSIONS * (TURNS as u64 - 1), "resume churn miscounted");

    // Close everything; the replica stays healthy afterwards.
    for si in 0..SESSIONS {
        req_id += 1;
        let rx = rep.submit(Request {
            id: req_id,
            prompt: vec![],
            max_tokens: 0,
            session: Some(SessionSpec { session_id: si, mode: SessionMode::Close }),
        });
        collect(&rx).unwrap_or_else(|e| panic!("close {si} failed: {e}"));
    }
    let s = tasks::passkey(&mut Rng::seed_from(9), 400, 0.6);
    let req = Request { id: req_id + 1, prompt: s.prompt.clone(), max_tokens: 2, session: None };
    let rx = rep.submit(req);
    let (tokens, _) = collect(&rx).unwrap();
    assert!(s.passed(&tokens), "replica unhealthy after soak");
}

/// The crash-recovery half of the soak: park durably, kill the whole
/// replica (process-crash stand-in: drop it, keep the spill dir), boot a
/// fresh replica over the same dir, and prove the boot scan hands back
/// the exact same continuation a never-crashed replica produces.
#[test]
fn park_crash_bootscan_resume_is_token_identical() {
    let mk_cfg = |dir: &std::path::Path| {
        let mut cfg = ServeConfig::default();
        cfg.model = "induction-mini".into();
        cfg.method = Method::RetrievalAttention;
        cfg.pattern = StaticPattern { sink: 32, window: 128 };
        cfg.retrieval.top_k = 32;
        cfg.retrieval.ef = 64;
        cfg.retrieval.maintenance.drain_watermark = 8;
        cfg.serving.session_cache.max_resident_bytes = 0; // every turn parks
        cfg.serving.session_cache.spill_dir = dir.to_string_lossy().into_owned();
        cfg.serving.session_cache.ephemeral_spill = false; // survive the "crash"
        cfg
    };
    let dir = std::env::temp_dir().join(format!("ra-soak-crash-{}", std::process::id()));
    let ctrl_dir = std::env::temp_dir().join(format!("ra-soak-ctrl-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&ctrl_dir);

    const SESSIONS: u64 = 4;
    let mut rng = Rng::seed_from(21);
    let samples: Vec<_> = (0..SESSIONS).map(|_| tasks::passkey(&mut rng, 400, 0.3)).collect();

    // Turn 1 on both replicas: identical prompts, identical answers, and
    // every session parked durably.
    let rep = Replica::spawn(mk_cfg(&dir));
    let ctrl = Replica::spawn(mk_cfg(&ctrl_dir));
    for (r, tag) in [(&rep, "victim"), (&ctrl, "control")] {
        for (si, s) in samples.iter().enumerate() {
            let rx = r.submit(Request {
                id: si as u64 + 1,
                prompt: s.prompt.clone(),
                max_tokens: 2,
                session: Some(SessionSpec { session_id: si as u64, mode: SessionMode::Open }),
            });
            let (tokens, _) =
                collect(&rx).unwrap_or_else(|e| panic!("{tag} open {si} failed: {e}"));
            assert!(s.passed(&tokens), "{tag} session {si}: wrong first answer");
        }
    }
    for si in 0..SESSIONS {
        assert!(
            dir.join(format!("session-{si}.ras")).exists(),
            "session {si} not parked durably before the crash"
        );
    }

    // Crash the victim: drop tears down the replica (worker, cache, RAM
    // state) but — durable tier — leaves the snapshots on disk.
    drop(rep);
    for si in 0..SESSIONS {
        assert!(
            dir.join(format!("session-{si}.ras")).exists(),
            "crash must not take session {si}'s snapshot with it"
        );
    }

    // Reboot over the same dir: the boot scan re-registers every parked
    // session; turn 2 resumes each one with tokens identical to the
    // control replica that never crashed.
    let rep = Replica::spawn(mk_cfg(&dir));
    for (si, _) in samples.iter().enumerate() {
        let cont = vec![9, si as u32 % 5 + 1, 4];
        let mut outs = Vec::new();
        for (r, tag) in [(&rep, "rebooted"), (&ctrl, "control")] {
            let rx = r.submit(Request {
                id: 100 + si as u64,
                prompt: cont.clone(),
                max_tokens: 3,
                session: Some(SessionSpec {
                    session_id: si as u64,
                    mode: SessionMode::Continue,
                }),
            });
            let (tokens, m) =
                collect(&rx).unwrap_or_else(|e| panic!("{tag} continue {si} failed: {e}"));
            assert!(m.resumed_from_disk, "{tag} session {si} must come off disk");
            assert!(m.snapshot_bytes > 0, "{tag} session {si}");
            outs.push(tokens);
        }
        assert_eq!(
            outs[0], outs[1],
            "session {si}: post-crash continuation diverged from control"
        );
    }

    drop(rep);
    drop(ctrl);
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&ctrl_dir);
}
