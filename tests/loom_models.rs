//! Exhaustive model checks for the repo's publish/swap protocols, run
//! under the vendored loom checker (`make loom`, i.e.
//! `RUSTFLAGS="--cfg loom" cargo test --test loom_models`). Under a
//! normal build this binary is empty — the facade in `util::sync` only
//! swaps to the instrumented types when `--cfg loom` is set.
//!
//! What is modeled (and why these four):
//!
//! 1. `Published::load_with_generation` — the (generation, snapshot)
//!    pair every swap in the repo is built on must never tear.
//! 2. The left/right double-buffer op-replay protocol of
//!    `IndexRetriever` — a reader must never observe a front that is
//!    mid-replay or returns an unmapped dense id.
//! 3. The reclamation publish order (map → store → fronts, previous map
//!    retained until `finish_remap`) — a reader holding ANY front must
//!    always find a same-generation id map for it.
//! 4. The maintenance worker's queue-depth accounting and stop-flag
//!    shutdown handshake.
//!
//! Plus a meta-test: deliberately inverting the publish order must make
//! the checker fail — proving the models have the power to catch the
//! bug class they guard against.
//!
//! Models must stay tiny: every atomic access and lock acquire is a
//! scheduling point, and the explorer enumerates all interleavings up
//! to the preemption bound. The real-code models below use 4-row
//! stores and 1-row batches so the FlatIndex scan stays on its inline
//! (single-threaded) path — `parallel::par_map` fan-outs would spawn
//! std threads the scheduler cannot see.

#![cfg(loom)]

use retrieval_attention::baselines::{GroupShared, HostRetriever, IndexRetriever};
use retrieval_attention::index::flat::FlatIndex;
use retrieval_attention::index::{KeyStore, RemapPlan, SearchParams};
use retrieval_attention::tensor::Matrix;
use retrieval_attention::util::swap::Published;
use std::sync::Arc;

/// Absolute ids are offset so a mapping bug (a dense id leaking through
/// unmapped) cannot masquerade as a valid result.
const ID_OFFSET: u32 = 100;
const D: usize = 4;

/// A 1-head retrieval group over an exact Flat index, sized so every
/// search runs inline (no thread fan-out inside the model).
fn tiny_head(n: usize) -> (Arc<GroupShared>, Arc<IndexRetriever>) {
    // Deterministic keys: models must not use RNG or wall clock.
    let keys =
        KeyStore::from_matrix(Matrix::from_fn(n, D, |r, c| ((r * D + c) % 7) as f32 - 3.0));
    let ids: Vec<u32> = (0..n as u32).map(|i| i + ID_OFFSET).collect();
    let group = GroupShared::new(keys, ids);
    let head = IndexRetriever::new(
        Box::new(FlatIndex::new(group.keys())),
        group.clone(),
        SearchParams::default(),
        "loom-flat",
    );
    (group, Arc::new(head))
}

/// Model 1: a `load_with_generation` pair is never torn. The writer
/// publishes vectors stamped with their own generation; any schedule in
/// which a reader sees a snapshot whose stamp disagrees with the
/// returned generation (or a half-written vector) fails the model.
#[test]
fn published_generation_snapshot_consistency() {
    loom::model(|| {
        let p = Arc::new(Published::new(vec![0u64; 4]));
        let writer = {
            let p = p.clone();
            loom::thread::spawn(move || {
                for g in 1..=2u64 {
                    p.publish(Arc::new(vec![g; 4]));
                }
            })
        };
        for _ in 0..2 {
            let (gen, snap) = p.load_with_generation();
            assert!(gen <= 2, "generation overran the writer");
            assert!(snap.iter().all(|&v| v == snap[0]), "torn snapshot");
            assert_eq!(snap[0], gen, "snapshot stamp disagrees with generation");
        }
        writer.join().unwrap();
        assert_eq!(p.generation(), 2);
    });
}

/// Model 2: the left/right double-buffer op replay. Two insert batches
/// force the full protocol — the second apply reclaims the displaced
/// front (the `Arc::try_unwrap` spin with its clone fallback) and
/// replays the pending op log onto it. A concurrent reader must always
/// see a complete front whose every dense id is mapped (an unmapped id
/// panics inside `retrieve` on the map indexing) and a monotone
/// generation.
#[test]
fn double_buffer_op_replay_is_atomic_to_readers() {
    loom::model(|| {
        let (group, head) = tiny_head(4);
        let writer = {
            let group = group.clone();
            let head = head.clone();
            loom::thread::spawn(move || {
                for b in 0..2u32 {
                    let rows = Matrix::from_fn(1, D, |_, c| (b + c as u32) as f32);
                    let ids = [ID_OFFSET + 4 + b];
                    // Map first, then store, then index — the drain order.
                    let store = group.extend(rows, &ids, true);
                    let ctx = retrieval_attention::index::InsertContext::none();
                    assert!(head.insert_batch(&store, &ids, &ctx), "insert refused");
                }
            })
        };
        let q = [1.0f32; D];
        let mut last_gen = 0;
        for _ in 0..2 {
            let gen = head.index_generation();
            assert!(gen >= last_gen, "index generation went backwards");
            last_gen = gen;
            let out = head.retrieve(&q, 4);
            for &id in &out.ids {
                assert!(
                    (ID_OFFSET..ID_OFFSET + 6).contains(&id),
                    "dense id leaked unmapped: {id}"
                );
            }
        }
        writer.join().unwrap();
        // Both ops landed exactly once: one generation bump per apply.
        assert_eq!(head.index_generation(), 2);
        assert_eq!(group.id_map().len(), 6);
        assert_eq!(group.keys().rows(), 6);
    });
}

/// Model 3: the reclamation epoch's publish order. The worker thread
/// runs the exact `CompactJob` sequence — tombstone, plan, publish the
/// remapped map+store under a bumped generation (old map retained as
/// `prev`), remap the front, release the old map. A reader holding any
/// front — pre-remap or post-remap — must always resolve a
/// same-generation map and never index it out of bounds. A wrong order
/// (front before map, or `prev` dropped early) surfaces as a panic or a
/// livelock (the retrieve retry never terminating), both model
/// failures.
#[test]
fn reclamation_publish_order_keeps_readers_mapped() {
    loom::model(|| {
        let (group, head) = tiny_head(4);
        let writer = {
            let group = group.clone();
            let head = head.clone();
            loom::thread::spawn(move || {
                // Tombstone the two oldest tokens, then run the epoch.
                assert!(head.remove_batch(&[ID_OFFSET, ID_OFFSET + 1]));
                let dead = head.dense_dead_ids();
                assert_eq!(dead, vec![0, 1]);
                let old_map = group.id_map();
                let gen = old_map.store_gen + 1;
                let (plan, keep) =
                    RemapPlan::from_dead(&dead, &group.keys(), gen).expect("plan");
                let new_ids: Vec<u32> = keep.iter().map(|&o| old_map.ids[o as usize]).collect();
                let new_store = plan.store.clone();
                let plan = Arc::new(plan);
                group.publish_remap(new_ids, new_store, gen);
                assert!(head.apply_remap(&plan), "remap refused");
                group.finish_remap();
            })
        };
        let q = [1.0f32; D];
        for _ in 0..2 {
            let out = head.retrieve(&q, 4);
            for &id in &out.ids {
                assert!(
                    (ID_OFFSET..ID_OFFSET + 4).contains(&id),
                    "dense id leaked unmapped: {id}"
                );
            }
        }
        writer.join().unwrap();
        // The epoch completed: generation bumped, dead rows physically gone.
        assert_eq!(group.store_generation(), 1);
        assert_eq!(group.keys().rows(), 2);
        assert_eq!(group.id_map().ids, vec![ID_OFFSET + 2, ID_OFFSET + 3]);
        let out = head.retrieve(&q, 4);
        assert!(!out.ids.contains(&ID_OFFSET), "reclaimed id resurfaced");
    });
}

/// Model 4: the maintenance worker's accounting protocol, mirrored with
/// modeled primitives (the real worker runs on a `std::thread` the
/// scheduler cannot see, so the protocol — not the struct — is what
/// gets checked): depth is incremented BEFORE enqueue and decremented
/// AFTER execution, so a sampled depth is always an upper bound on
/// completed-but-uncounted work and reconciles to zero at shutdown; the
/// stop flag is Release-stored after the final enqueue and
/// Acquire-loaded only on an empty queue, so no job is lost across
/// shutdown.
#[test]
fn worker_queue_depth_accounting_and_shutdown() {
    use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use loom::sync::Mutex;
    loom::model(|| {
        let depth = Arc::new(AtomicUsize::new(0));
        let queue = Arc::new(Mutex::new(Vec::<u32>::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let executed = Arc::new(AtomicUsize::new(0));
        let worker = {
            let depth = depth.clone();
            let queue = queue.clone();
            let stop = stop.clone();
            let executed = executed.clone();
            loom::thread::spawn(move || loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    Some(_) => {
                        // "Execute", then decrement — the queue-depth
                        // gauge must stay conservative (never report
                        // idle while a job is still running).
                        executed.fetch_add(1, Ordering::SeqCst);
                        depth.fetch_sub(1, Ordering::SeqCst);
                    }
                    None => {
                        if stop.load(Ordering::Acquire) {
                            break;
                        }
                        loom::thread::yield_now();
                    }
                }
            })
        };
        for j in 0..2u32 {
            // Increment BEFORE enqueue, mirroring `WorkerHandle::submit`.
            let outstanding = depth.fetch_add(1, Ordering::SeqCst);
            assert!(outstanding <= 1, "depth exceeded outstanding jobs");
            queue.lock().unwrap().push(j);
        }
        stop.store(true, Ordering::Release);
        worker.join().unwrap();
        assert_eq!(executed.load(Ordering::SeqCst), 2, "job lost across shutdown");
        assert_eq!(depth.load(Ordering::SeqCst), 0, "depth did not reconcile to zero");
        assert!(queue.lock().unwrap().is_empty(), "queue not drained at shutdown");
    });
}

/// Model 5: the replica slot protocol on the REAL
/// [`retrieval_attention::coordinator::scheduler::SlotBoard`] (whose
/// atomics are the loom facade's under `--cfg loom`): a submitter
/// enters jobs onto the board before queueing them and raises the stop
/// flag after the last one; the worker drains the queue in waves
/// ([`pick_wave`] selects within each wave), publishes each job's
/// result, and only then retires its slot. The invariant under every
/// schedule: an observer that sees the board drain (`in_flight() == 0`
/// after stop) must also see every published result — exactly the
/// contract clients of `Replica::outstanding` rely on.
fn slot_protocol_model(retire_before_publish: bool) {
    use loom::sync::atomic::{AtomicBool, Ordering};
    use loom::sync::Mutex;
    use retrieval_attention::coordinator::scheduler::{pick_wave, SlotBoard};
    loom::model(move || {
        let board = Arc::new(SlotBoard::new());
        let queue = Arc::new(Mutex::new(Vec::<usize>::new()));
        let published: Arc<[AtomicBool; 2]> =
            Arc::new([AtomicBool::new(false), AtomicBool::new(false)]);
        let submitter = {
            let board = board.clone();
            let queue = queue.clone();
            loom::thread::spawn(move || {
                for j in 0..2usize {
                    // Enter BEFORE the queue push (`Replica::submit`): the
                    // job must never be in flight yet invisible.
                    board.enter();
                    queue.lock().unwrap().push(j);
                }
                board.raise_stop();
            })
        };
        let worker = {
            let board = board.clone();
            let queue = queue.clone();
            let published = published.clone();
            loom::thread::spawn(move || loop {
                // One wave: take whatever is queued, pick within it.
                let wave: Vec<usize> = std::mem::take(&mut *queue.lock().unwrap());
                board.set_queued(0);
                if wave.is_empty() {
                    if board.stopped() {
                        break;
                    }
                    loom::thread::yield_now();
                    continue;
                }
                let waited = vec![0u64; wave.len()];
                let seq: Vec<u64> = (0..wave.len() as u64).collect();
                for &i in &pick_wave(0, 4, &waited, &seq) {
                    let j = wave[i];
                    if retire_before_publish {
                        // The BUG the meta-test below must catch: the
                        // slot frees before the result exists.
                        board.retire();
                        published[j].store(true, Ordering::Release);
                    } else {
                        // Publish-then-retire: the real retirement order.
                        published[j].store(true, Ordering::Release);
                        board.retire();
                    }
                }
            })
        };
        // Observer: once the stop flag is visible every enter() is too
        // (raise_stop is Release-after-enters); then wait for the drain.
        loop {
            if board.stopped() && board.in_flight() == 0 {
                break;
            }
            loom::thread::yield_now();
        }
        for (j, flag) in published.iter().enumerate() {
            assert!(
                flag.load(Ordering::Acquire),
                "board drained but job {j}'s result was never published"
            );
        }
        submitter.join().unwrap();
        worker.join().unwrap();
        assert_eq!(board.in_flight(), 0);
        assert_eq!(board.queued(), 0);
    });
}

/// The slot protocol holds under every interleaving.
#[test]
fn slot_protocol_publish_then_retire_holds() {
    slot_protocol_model(false);
}

/// Meta-test: retiring a slot BEFORE publishing its result must be
/// caught — there is a schedule where the observer sees the board drain
/// while a result is still unpublished, and the explorer must find it.
#[test]
fn slot_protocol_retire_before_publish_is_caught() {
    let result = std::panic::catch_unwind(|| slot_protocol_model(true));
    assert!(result.is_err(), "model checker missed retire-before-publish");
}

/// Protocol mirror of the map-before-front invariant: the "index front"
/// here is just the highest dense id a search may return, the map the
/// vector it must index into. Publishing the map first keeps every
/// reader in bounds; the inverted order leaves a window where the front
/// references a row the map does not have yet.
fn publish_order_model(invert: bool) {
    loom::model(move || {
        let map = Arc::new(Published::new(vec![ID_OFFSET]));
        let front = Arc::new(Published::new(0usize));
        let writer = {
            let map = map.clone();
            let front = front.clone();
            loom::thread::spawn(move || {
                if invert {
                    front.publish(Arc::new(1usize));
                    map.publish(Arc::new(vec![ID_OFFSET, ID_OFFSET + 1]));
                } else {
                    map.publish(Arc::new(vec![ID_OFFSET, ID_OFFSET + 1]));
                    front.publish(Arc::new(1usize));
                }
            })
        };
        // Snapshot order front-then-map — the reverse of publish order,
        // exactly like `IndexRetriever::retrieve`.
        let dense = *front.load();
        let ids = map.load();
        let abs = ids[dense];
        assert!(abs >= ID_OFFSET);
        writer.join().unwrap();
    });
}

/// The invariant the whole repo rests on, in its smallest form.
#[test]
fn publish_order_map_before_front_holds() {
    publish_order_model(false);
}

/// Meta-test: the checker must CATCH the deliberately inverted publish
/// order — there exists a schedule where the reader indexes out of
/// bounds, and the explorer must find it. If this test fails, the
/// models above are not actually exercising the interleavings they
/// claim to.
#[test]
fn inverted_publish_order_is_caught() {
    let result = std::panic::catch_unwind(|| publish_order_model(true));
    assert!(result.is_err(), "model checker missed the inverted publish order");
}
