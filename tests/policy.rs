//! Head-policy layer tests: the streaming tier's attention semantics
//! and the calibrated Retrieval→Streaming flip, end to end.
//!
//! The load-bearing claims:
//!
//! 1. **Span restriction**: a streaming head's host partial is exactly
//!    full attention restricted to the sink+window id set — the
//!    retriever returns precisely that span, and `attend_subset` over it
//!    matches a from-scratch softmax reference (property-tested over
//!    random keys/queries and span geometries).
//! 2. **Live specialization**: a calibrated session starts all-retrieval,
//!    flips qualifying heads after the profiling budget, releases the
//!    flipped heads' index bytes, and keeps decoding.

use retrieval_attention::attention::attend_subset;
use retrieval_attention::baselines::{HostRetriever, RetrieverInputs, StreamingRetriever};
use retrieval_attention::config::{RetrievalConfig, ServeConfig};
use retrieval_attention::index::KeyStore;
use retrieval_attention::kvcache::StaticPattern;
use retrieval_attention::model::Engine;
use retrieval_attention::policy::PolicyMode;
use retrieval_attention::tensor::Matrix;
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::tasks;

/// Reference restricted attention: plain two-pass softmax over exactly
/// the given rows, accumulated in f64 so rounding differences from the
/// production kernel stay within float tolerance.
fn reference_attention(q: &[f32], keys: &Matrix, values: &Matrix, ids: &[u32], scale: f32) -> (Vec<f32>, f32) {
    let d = values.cols();
    let logits: Vec<f64> = ids
        .iter()
        .map(|&id| {
            let k = keys.row(id as usize);
            q.iter().zip(k).map(|(&a, &b)| a as f64 * b as f64).sum::<f64>() * scale as f64
        })
        .collect();
    let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let weights: Vec<f64> = logits.iter().map(|&l| (l - m).exp()).collect();
    let z: f64 = weights.iter().sum();
    let mut o = vec![0.0f64; d];
    for (w, &id) in weights.iter().zip(ids) {
        for (acc, &v) in o.iter_mut().zip(values.row(id as usize)) {
            *acc += w * v as f64;
        }
    }
    (o.iter().map(|&x| (x / z) as f32).collect(), (m + z.ln()) as f32)
}

#[test]
fn streaming_head_partial_is_full_attention_restricted_to_its_span() {
    let mut rng = Rng::seed_from(101);
    let d = 16usize;
    let scale = 1.0 / (d as f32).sqrt();
    // Span geometries: truncating, exactly-covering, and over-covering
    // (short map ⇒ the whole history, i.e. unrestricted full attention).
    for (n, sinks, window) in [(96usize, 8usize, 16usize), (24, 8, 16), (12, 8, 16), (64, 0, 32)] {
        let keys = Matrix::from_fn(n, d, |_, _| rng.normal());
        let values = Matrix::from_fn(n, d, |_, _| rng.normal());
        let queries = Matrix::from_fn(4, d, |_, _| rng.normal());
        let cfg = RetrievalConfig::default();
        let inp = RetrieverInputs::from_parts(
            KeyStore::from_matrix(keys.clone()),
            (0..n as u32).collect(),
            &queries,
            scale,
            &cfg,
            7,
        );
        let head = StreamingRetriever::new(inp.group.clone(), sinks, window);
        let expected: Vec<u32> = if n <= sinks + window {
            (0..n as u32).collect()
        } else {
            (0..sinks as u32).chain((n - window) as u32..n as u32).collect()
        };
        for trial in 0..20 {
            let q: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let got = head.retrieve(&q, 0);
            assert_eq!(got.ids, expected, "n={n} sinks={sinks} window={window}: wrong span");
            assert_eq!(got.scanned, 0, "streaming head must not report index scans");
            let p = attend_subset(&q, &keys, &values, &got.ids, scale);
            let (ro, rlse) = reference_attention(&q, &keys, &values, &expected, scale);
            assert!(
                (p.lse - rlse).abs() < 1e-4,
                "n={n} trial={trial}: lse {} vs reference {rlse}",
                p.lse
            );
            for (i, (&a, &b)) in p.o.iter().zip(&ro).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "n={n} trial={trial}: output[{i}] {a} vs reference {b}"
                );
            }
        }
    }
}

fn calibrated_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.pattern = StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.maintenance.async_worker = false;
    cfg.retrieval.maintenance.drain_watermark = 16;
    cfg.policy.mode = PolicyMode::Calibrated;
    cfg.policy.calibration_steps = 2;
    cfg.policy.sinks = 8;
    cfg.policy.window = 32;
    cfg
}

#[test]
fn calibrated_session_flips_heads_and_keeps_decoding() {
    // Threshold 0: every head qualifies, so the flip is guaranteed once
    // the profiling budget is spent — the live-swap path under test.
    let mut cfg = calibrated_cfg();
    cfg.policy.mass_threshold = 0.0;
    let eng = Engine::from_config(cfg).expect("engine init");
    let mut rng = Rng::seed_from(103);
    let s = tasks::passkey(&mut rng, 400, 0.4);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    assert_eq!(sess.streaming_fraction(), 0.0, "calibrated sessions start all-retrieval");
    assert!(sess.calib.is_some(), "no calibrator attached");
    let before = sess.index_memory_bytes();

    // generate(3) = first token from the prefill state + 2 decode steps,
    // exactly the calibration budget.
    let (tokens, _) = eng.generate(&mut sess, 3).unwrap();
    assert_eq!(tokens.len(), 3);
    assert_eq!(sess.streaming_fraction(), 1.0, "flip did not land after the budget");
    assert!(sess.calib.is_none(), "calibrator must retire after deciding");
    assert!(
        sess.index_bytes_avoided > 0,
        "flip released no index bytes (indexes were non-empty before it)"
    );
    assert!(
        sess.index_memory_bytes() < before,
        "per-head index memory did not shrink after the flip"
    );

    // The specialized session keeps decoding (streaming heads now feed
    // the combine step from their sink+window span only).
    let mut tok = 5u32;
    for _ in 0..6 {
        tok = eng.decode_step(&mut sess, tok).unwrap().token;
        assert!((tok as usize) < eng.spec().vocab);
    }
    sess.shutdown_maintenance();
}

#[test]
fn unreachable_threshold_never_flips() {
    // Mass can never exceed 1, so threshold 2 pins every head on the
    // retrieval tier through the same calibration machinery.
    let mut cfg = calibrated_cfg();
    cfg.policy.mass_threshold = 2.0;
    let eng = Engine::from_config(cfg).expect("engine init");
    let mut rng = Rng::seed_from(107);
    let s = tasks::passkey(&mut rng, 400, 0.6);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let (tokens, _) = eng.generate(&mut sess, 4).unwrap();
    assert_eq!(tokens.len(), 4);
    assert_eq!(sess.streaming_fraction(), 0.0, "nothing should qualify at threshold 2");
    assert!(sess.calib.is_none(), "calibrator still live past its budget");
    assert_eq!(sess.index_bytes_avoided, 0);
    sess.shutdown_maintenance();
}
