//! Concurrency + reconciliation suite for the off-thread maintenance
//! subsystem (seeded multi-thread stress in lieu of loom; run serialized
//! in CI: `cargo test -q --test maintenance_concurrency -- --test-threads=1`
//! under a timeout so a deadlocked worker fails fast).
//!
//! Invariants under test:
//! * decode-side readers never observe a partially-swapped index: every
//!   search runs against a complete front snapshot, every returned id is
//!   mapped by the (at-least-as-new) group id map, and the generation
//!   counter is monotone;
//! * after worker shutdown, drain counts reconcile *exactly* with the
//!   inserted ids: each head's live index size equals its cache's indexed
//!   tier, and the session-level drained-token counter equals the summed
//!   boundary advance.

use retrieval_attention::baselines::{build_retriever, GroupShared, HostRetriever, RetrieverInputs};
use retrieval_attention::config::{Method, RetrievalConfig, ServeConfig};
use retrieval_attention::index::KeyStore;
use retrieval_attention::model::Engine;
use retrieval_attention::tensor::Matrix;
use retrieval_attention::util::rng::Rng;
use retrieval_attention::util::swap::Published;
use retrieval_attention::workload::tasks;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Absolute ids are offset so a mapping bug (returning dense ids raw)
/// cannot masquerade as a valid result.
const ID_OFFSET: u32 = 10_000;

fn build_head(
    method: Method,
    n: usize,
    d: usize,
    seed: u64,
) -> (Arc<GroupShared>, Arc<dyn HostRetriever>) {
    let mut rng = Rng::seed_from(seed);
    let keys = KeyStore::from_matrix(Matrix::from_fn(n, d, |_, _| rng.normal()));
    let ids: Vec<u32> = (0..n as u32).map(|i| i + ID_OFFSET).collect();
    let group = GroupShared::new(keys, ids);
    let queries = Matrix::from_fn(48, d, |_, c| rng.normal() + if c == 0 { 1.5 } else { 0.0 });
    let cfg = RetrievalConfig::default();
    let inp = RetrieverInputs {
        group: group.clone(),
        prefill_queries: &queries,
        scale: 0.3,
        cfg: &cfg,
        seed,
    };
    let head: Arc<dyn HostRetriever> = Arc::from(build_retriever(method, inp));
    (group, head)
}

/// Readers hammer `retrieve` while a writer drains insert batches and
/// interleaves removals; every observation must be internally consistent.
fn stress_method(method: Method, seed: u64) {
    const D: usize = 8;
    const BASE: usize = 96;
    const BATCHES: usize = 30;
    const BATCH: usize = 8;
    let (group, head) = build_head(method, BASE, D, seed);

    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for t in 0..3u64 {
        let head = head.clone();
        let group = group.clone();
        let stop = stop.clone();
        readers.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from(seed ^ (t + 1) * 0x9E37);
            let mut last_gen = 0u64;
            let mut observed = 0usize;
            while !stop.load(Ordering::Acquire) {
                let gen = head.index_generation();
                assert!(gen >= last_gen, "generation went backwards: {last_gen} -> {gen}");
                last_gen = gen;
                let q: Vec<f32> = (0..D).map(|_| rng.normal()).collect();
                // A torn swap would surface here as an out-of-range dense
                // id (panic on map indexing inside retrieve) or an
                // unmapped absolute id below.
                let out = head.retrieve(&q, 10);
                let map = group.id_map();
                for &id in &out.ids {
                    assert!(id >= ID_OFFSET, "dense id leaked unmapped: {id}");
                    assert!(
                        map.binary_search(&id).is_ok(),
                        "returned id {id} not in the published map"
                    );
                }
                observed += 1;
            }
            observed
        }));
    }

    // Writer: drain batches through the group-extend + head-insert path
    // (the exact op order the worker uses), removing a sprinkle of older
    // ids along the way. The final batch carries a planted dominant key so
    // the post-stress probe is deterministic for every family.
    let mut rng = Rng::seed_from(seed ^ 0xDEAD);
    let mut total = BASE;
    let mut removed = 0usize;
    for b in 0..BATCHES {
        let planted = b == BATCHES - 1;
        let rows = Matrix::from_fn(BATCH, D, |r, _| {
            if planted && r == BATCH - 1 {
                3.0
            } else {
                rng.normal()
            }
        });
        let ids: Vec<u32> = (total as u32..(total + BATCH) as u32).map(|i| i + ID_OFFSET).collect();
        let store = group.extend(rows, &ids, true);
        let ctx = retrieval_attention::index::InsertContext::none();
        assert!(head.insert_batch(&store, &ids, &ctx), "{method:?} insert refused at batch {b}");
        total += BATCH;
        if b % 5 == 4 && head.supports_remove() {
            // Remove one id from the oldest live region.
            let victim = ID_OFFSET + (removed as u32);
            assert!(head.remove_batch(&[victim]));
            removed += 1;
        }
    }
    stop.store(true, Ordering::Release);
    for r in readers {
        let observed = r.join().expect("reader panicked");
        assert!(observed > 0, "reader made no observations");
    }

    // Reconciliation: dense slots == base + all inserted ids; tombstones
    // == removals; the map covers every slot.
    assert_eq!(group.id_map().len(), total);
    assert_eq!(group.keys().rows(), total);
    if head.supports_remove() {
        assert_eq!(head.tombstones(), removed);
        assert_eq!(head.indexed_len(), Some(total - removed));
    } else {
        assert_eq!(head.indexed_len(), Some(total));
    }
    // One generation bump per applied op (inserts + removes), never more.
    let ops = BATCHES as u64 + if head.supports_remove() { removed as u64 } else { 0 };
    assert_eq!(head.index_generation(), ops, "{method:?}: swap count mismatch");
    // The planted dominant key (last row of the final batch) is searchable
    // under its absolute id: its self-inner-product (3.0² × d) towers over
    // every random key, so any correctly-wired family must surface it.
    let probe_dense = total - 1;
    let q = group.keys().row(probe_dense).to_vec();
    let out = head.retrieve(&q, 16);
    assert!(
        out.ids.contains(&(probe_dense as u32 + ID_OFFSET)),
        "{method:?}: inserted key unreachable after stress"
    );
}

#[test]
fn flat_swap_never_partial_under_stress() {
    stress_method(Method::Flat, 0xF1A7);
}

#[test]
fn ivf_swap_never_partial_under_stress() {
    stress_method(Method::Ivf, 0x1BF5);
}

#[test]
fn hnsw_swap_never_partial_under_stress() {
    stress_method(Method::Hnsw, 0x45CA);
}

#[test]
fn roargraph_swap_never_partial_under_stress() {
    stress_method(Method::RetrievalAttention, 0x0A27);
}

#[test]
fn published_generation_pairs_with_snapshot_under_contention() {
    // Writer publishes vectors stamped with their generation; readers must
    // never see a vector whose stamp disagrees with itself (torn state) or
    // a (generation, snapshot) pair where the snapshot is older than the
    // generation claims.
    let p = Arc::new(Published::new(vec![0u64; 32]));
    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for _ in 0..4 {
        let p = p.clone();
        let stop = stop.clone();
        readers.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                let (gen, snap) = p.load_with_generation();
                let stamp = snap[0];
                assert!(snap.iter().all(|&v| v == stamp), "torn snapshot");
                assert!(stamp == gen, "snapshot stamp {stamp} != generation {gen}");
            }
        }));
    }
    for g in 1..=2000u64 {
        p.publish(Arc::new(vec![g; 32]));
    }
    stop.store(true, Ordering::Release);
    for r in readers {
        r.join().expect("reader panicked");
    }
}

fn concurrency_engine(watermark: usize) -> Engine {
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = Method::RetrievalAttention;
    cfg.pattern = retrieval_attention::kvcache::StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.ef = 64;
    cfg.retrieval.maintenance.drain_watermark = watermark;
    cfg.retrieval.maintenance.recent_queries = 16;
    cfg.retrieval.maintenance.async_worker = true;
    Engine::from_config(cfg).expect("engine init")
}

#[test]
fn engine_worker_drains_reconcile_exactly_after_shutdown() {
    let eng = concurrency_engine(8);
    let mut rng = Rng::seed_from(99);
    let s = tasks::passkey(&mut rng, 500, 0.4);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let before: Vec<Vec<usize>> = sess
        .caches
        .iter()
        .map(|layer| layer.iter().map(|c| c.indexed_end()).collect())
        .collect();
    let _ = eng.generate(&mut sess, 48).unwrap();
    sess.shutdown_maintenance();
    assert!(sess.maint.inflight.is_empty(), "jobs still marked in flight after shutdown");
    assert!(sess.drains > 0, "48 tokens past watermark 8 must drain");

    // Drain counters reconcile exactly with the advanced boundaries.
    let mut advanced = 0u64;
    for (layer, caches) in sess.caches.iter().enumerate() {
        for (kvh, cache) in caches.iter().enumerate() {
            advanced += (cache.indexed_end() - before[layer][kvh]) as u64;
            // Every head's live index matches its cache's indexed tier.
            let group = eng.spec().group_size();
            for g in 0..group {
                let r = &sess.retrievers[layer][kvh * group + g];
                assert_eq!(
                    r.indexed_len(),
                    Some(cache.indexed_len()),
                    "layer {layer} kvh {kvh} head {g}: index diverged from cache"
                );
                assert!(r.index_generation() > 0, "worker never swapped this head");
            }
            // The group map mirrors the indexed tier one-to-one.
            assert_eq!(sess.groups[layer][kvh].id_map().len(), cache.indexed_len());
        }
    }
    assert_eq!(advanced, sess.drained_tokens, "drain counter != boundary advance");
    assert_eq!(sess.maint.stats.swaps, sess.drains, "one swap completion per drain");
    assert!(sess.maint.stats.swap_s_total >= 0.0);
}

/// Long-horizon streaming soak: drive ≥10× `max_indexed` tokens through
/// the drain → retire → reclaim loop and assert host/store bytes stay
/// BOUNDED after each epoch — the tentpole property (bounded attention
/// became bounded memory). Runs with the worker on and off so the
/// serialized CI job covers both the concurrent and the inline epoch.
fn reclaim_soak(async_worker: bool, seed: u64) {
    const MAX_INDEXED: usize = 48;
    const WATERMARK: usize = 8;
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = Method::RetrievalAttention;
    cfg.pattern = retrieval_attention::kvcache::StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.ef = 64;
    cfg.retrieval.maintenance.drain_watermark = WATERMARK;
    cfg.retrieval.maintenance.recent_queries = 8;
    cfg.retrieval.maintenance.async_worker = async_worker;
    cfg.retrieval.eviction.max_indexed = MAX_INDEXED;
    cfg.retrieval.eviction.reclaim_ratio = 0.25;
    let eng = Engine::from_config(cfg).expect("engine init");
    let mut rng = Rng::seed_from(seed);
    let s = tasks::passkey(&mut rng, 300, 0.5);
    let mut sess = eng.prefill(&s.prompt).unwrap();

    // Bound on physical rows per group: the live tier (max_indexed plus
    // a few drain batches of async lag), the tombstones tolerated below
    // the 0.25 trigger, and fresh tombstones awaiting the next pass. The
    // exact steady state is ~1.3× max_indexed; the bound is generous to
    // absorb worker-scheduling lag while staying far below the ~620 rows
    // an unbounded session would accumulate.
    let live_bound = MAX_INDEXED + 4 * WATERMARK;
    let rows_bound = 2 * live_bound;
    let spec = eng.spec().clone();
    let dh = spec.head_dim;

    let mut tok = 1u32;
    let mut last_gen = vec![vec![0u64; spec.kv_heads]; spec.layers];
    for epoch in 0..12 {
        for _ in 0..40 {
            tok = eng.decode_step(&mut sess, tok % 97).unwrap().token;
        }
        sess.flush_maintenance();
        for layer in 0..spec.layers {
            for kvh in 0..spec.kv_heads {
                let rows = sess.host_store(layer, kvh).rows();
                let group = &sess.groups[layer][kvh];
                assert_eq!(group.id_map().len(), rows, "map/store diverged");
                // Store generations are monotone (epochs only bump).
                let gen = group.store_generation();
                assert!(gen >= last_gen[layer][kvh], "generation went backwards");
                last_gen[layer][kvh] = gen;
                // Epoch 0 may still be digesting the prefill backlog (the
                // initial 140-row tier retires through the queue); from
                // epoch 1 on the bounds must hold at every check.
                if epoch == 0 {
                    continue;
                }
                assert!(
                    rows <= rows_bound,
                    "epoch {epoch} layer {layer} kvh {kvh}: store rows {rows} unbounded \
                     (bound {rows_bound})"
                );
                assert!(
                    group.store_bytes() <= rows_bound * dh * 4 + 4096,
                    "store bytes unbounded"
                );
                assert!(
                    sess.caches[layer][kvh].indexed_len() <= live_bound,
                    "live tier not bounded by the eviction budget"
                );
            }
        }
    }
    sess.shutdown_maintenance();
    // 480 decoded tokens through a 48-token budget: many retirements and
    // several reclamation epochs must have happened.
    assert!(sess.maint.stats.evicted_tokens > 0, "eviction never fired");
    assert!(sess.maint.stats.reclaims >= 2, "reclaim epochs: {}", sess.maint.stats.reclaims);
    assert!(sess.maint.stats.reclaimed_rows as usize >= MAX_INDEXED);
    assert!(last_gen[0][0] >= 1, "no generation bump on layer 0");

    // Post-soak correctness: live indexed keys retrieve their own ids;
    // nothing retired is ever surfaced.
    let cache = &sess.caches[0][0];
    let live_ids = cache.indexed_ids();
    assert!(!live_ids.is_empty(), "soak left an empty indexed tier");
    let mut hits = 0;
    let probes: Vec<u32> = live_ids.iter().copied().step_by(7).take(5).collect();
    for &id in &probes {
        let out = sess.retrievers[0][0].retrieve(cache.key(id as usize), 32);
        if out.ids.contains(&id) {
            hits += 1;
        }
        for got in &out.ids {
            assert!(!cache.is_retired(*got as usize), "retired id {got} retrieved");
        }
    }
    assert!(hits >= probes.len() - 1, "live keys unretrievable: {hits}/{}", probes.len());
    // The session keeps decoding after shutdown (a fresh worker respawns).
    let out = eng.decode_step(&mut sess, 2).unwrap();
    let _ = out.token;
}

#[test]
fn reclaim_soak_bounds_memory_with_async_worker() {
    reclaim_soak(true, 0x50AC);
}

#[test]
fn reclaim_soak_bounds_memory_inline() {
    reclaim_soak(false, 0x50AD);
}

#[test]
fn worker_shutdown_is_prompt_and_idempotent() {
    // A deadlocked worker would hang here (the CI job wraps this whole
    // binary in a `timeout` as the last line of defense).
    let eng = concurrency_engine(4);
    let mut rng = Rng::seed_from(7);
    let s = tasks::passkey(&mut rng, 400, 0.5);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let _ = eng.generate(&mut sess, 12).unwrap();
    sess.shutdown_maintenance();
    let drained = sess.drained_tokens;
    // Idempotent: a second shutdown must not wedge or double-count, and a
    // later decode step transparently respawns a fresh worker.
    sess.shutdown_maintenance();
    assert_eq!(sess.drained_tokens, drained);
    let out = eng.decode_step(&mut sess, 1).unwrap();
    let _ = out.token;
}
