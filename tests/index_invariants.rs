//! Property-based invariants over the ANNS substrate and the attention
//! engine (the in-crate `util::prop` driver replays failures by seed).

use retrieval_attention::attention::{attend_subset, combine, full_attention};
use retrieval_attention::index::{
    exact_topk, flat::FlatIndex, hnsw::{HnswIndex, HnswParams}, ivf::IvfIndex,
    roargraph::{RoarGraph, RoarParams}, search_rerank, InsertContext, KeyStore, RemapPlan,
    SearchParams, VectorIndex,
};
use retrieval_attention::kernel::{self, QuantMode};
use retrieval_attention::prop_assert;
use retrieval_attention::tensor::Matrix;
use retrieval_attention::util::prop::check;
use retrieval_attention::util::rng::Rng;
use std::sync::Arc;

fn random_setup(rng: &mut Rng) -> (Arc<Matrix>, Matrix, Vec<f32>) {
    let n = 64 + rng.below(448);
    let d = [8usize, 16, 32, 64][rng.below(4)];
    let keys = {
        let mut r = rng.fork(1);
        Arc::new(Matrix::from_fn(n, d, |_, _| r.normal()))
    };
    let queries = {
        let mut r = rng.fork(2);
        Matrix::from_fn(32, d, |_, c| r.normal() + if c == 0 { 2.0 } else { 0.0 })
    };
    let q = {
        let mut r = rng.fork(3);
        (0..d).map(|_| r.normal()).collect()
    };
    (keys, queries, q)
}

#[test]
fn prop_flat_always_matches_exact_topk() {
    check("flat == exact", 25, |rng| {
        let (keys, _, q) = random_setup(rng);
        let k = 1 + rng.below(20);
        let idx = FlatIndex::new(keys.clone());
        let got = idx.search(&q, k, &SearchParams::default());
        let want = exact_topk(&keys, &q, k);
        prop_assert!(got.ids == want, "flat diverged from exact: {:?} vs {:?}", got.ids, want);
        Ok(())
    });
}

#[test]
fn prop_search_results_sorted_and_unique() {
    check("sorted unique results", 15, |rng| {
        let (keys, queries, q) = random_setup(rng);
        let indexes: Vec<Box<dyn VectorIndex>> = vec![
            Box::new(FlatIndex::new(keys.clone())),
            Box::new(IvfIndex::build(keys.clone(), Some(16), 1)),
            Box::new(HnswIndex::build(keys.clone(), HnswParams::default())),
            Box::new(RoarGraph::build(keys.clone(), &queries, RoarParams::default())),
        ];
        for idx in &indexes {
            let r = idx.search(&q, 10, &SearchParams::default());
            for w in r.scores.windows(2) {
                prop_assert!(w[0] >= w[1], "{}: scores not sorted", idx.name());
            }
            let mut ids = r.ids.clone();
            ids.sort_unstable();
            let before = ids.len();
            ids.dedup();
            prop_assert!(ids.len() == before, "{}: duplicate ids", idx.name());
            prop_assert!(
                r.ids.iter().all(|&i| (i as usize) < keys.rows()),
                "{}: id out of range",
                idx.name()
            );
            prop_assert!(r.scanned <= keys.rows() + 64, "{}: scanned > n", idx.name());
        }
        Ok(())
    });
}

#[test]
fn prop_returned_scores_are_true_inner_products() {
    check("scores are q.k", 15, |rng| {
        let (keys, queries, q) = random_setup(rng);
        let idx = RoarGraph::build(keys.clone(), &queries, RoarParams::default());
        let r = idx.search(&q, 5, &SearchParams::default());
        for (&id, &s) in r.ids.iter().zip(r.scores.iter()) {
            let expect = retrieval_attention::tensor::dot(&q, keys.row(id as usize));
            prop_assert!((s - expect).abs() < 1e-4, "score mismatch: {s} vs {expect}");
        }
        Ok(())
    });
}

#[test]
fn prop_combine_equals_joint_attention() {
    // For ANY disjoint partition of tokens into m parts, combining the
    // partials equals full attention — Appendix B.1 as a property.
    check("combine exactness", 25, |rng| {
        let n = 16 + rng.below(200);
        let d = 4 + rng.below(28);
        let mut r1 = rng.fork(1);
        let keys = Matrix::from_fn(n, d, |_, _| r1.normal());
        let values = Matrix::from_fn(n, d, |_, _| r1.normal());
        let q: Vec<f32> = (0..d).map(|_| r1.normal()).collect();
        let scale = 0.05 + rng.f32();

        // Random partition into 2-4 parts.
        let parts = 2 + rng.below(3);
        let mut assignment: Vec<usize> = (0..n).map(|_| rng.below(parts)).collect();
        assignment[0] = 0; // ensure part 0 non-empty
        let partials: Vec<_> = (0..parts)
            .map(|p| {
                let ids: Vec<u32> = assignment
                    .iter()
                    .enumerate()
                    .filter(|(_, &a)| a == p)
                    .map(|(i, _)| i as u32)
                    .collect();
                attend_subset(&q, &keys, &values, &ids, scale)
            })
            .collect();
        let merged = combine(&partials);
        let want = full_attention(&q, &keys, &values, scale);
        for (a, b) in merged.o.iter().zip(want.iter()) {
            prop_assert!((a - b).abs() < 1e-4, "combine mismatch {a} vs {b}");
        }
        Ok(())
    });
}

#[test]
fn prop_ivf_recall_monotone_in_nprobe() {
    check("ivf monotone", 10, |rng| {
        let (keys, _, q) = random_setup(rng);
        let idx = IvfIndex::build(keys.clone(), Some(16), 3);
        let truth = exact_topk(&keys, &q, 10);
        let mut last = -1.0f32;
        for nprobe in [1usize, 2, 4, 8, 16] {
            let r = idx.search(&q, 10, &SearchParams { ef: 0, nprobe });
            let rec = r.recall_against(&truth);
            prop_assert!(rec >= last - 1e-6, "recall not monotone at nprobe={nprobe}");
            last = rec;
        }
        prop_assert!((last - 1.0).abs() < 1e-6, "full probe must be exact");
        Ok(())
    });
}

#[test]
fn prop_roargraph_reaches_everything_with_huge_ef() {
    check("roargraph connectivity", 8, |rng| {
        let (keys, queries, _) = random_setup(rng);
        let n = keys.rows();
        let idx = RoarGraph::build(keys.clone(), &queries, RoarParams::default());
        let mut r = rng.fork(9);
        let q: Vec<f32> = (0..keys.cols()).map(|_| r.normal()).collect();
        let res = idx.search(&q, n, &SearchParams { ef: n, nprobe: 0 });
        prop_assert!(res.ids.len() == n, "unreachable nodes: {} < {n}", res.ids.len());
        Ok(())
    });
}

#[test]
fn prop_insert_then_search_within_epsilon_of_rebuild() {
    // The online-maintenance contract: for every index family, building on
    // a base set then folding in a batch via `insert_batch` must retrieve
    // like a from-scratch build over the same vectors — recall@10 within
    // ε = 0.05 (averaged over a query panel). A broken insert (unreachable
    // or unmapped nodes) collapses recall and fails loudly.
    check("insert ~ rebuild recall", 6, |rng| {
        let n = 128 + rng.below(128);
        let extra = 32 + rng.below(64);
        let d = [8usize, 16, 32][rng.below(3)];
        let total = n + extra;
        let all = {
            let mut r = rng.fork(1);
            Arc::new(Matrix::from_fn(total, d, |_, _| r.normal()))
        };
        let base = KeyStore::from_matrix(Matrix::from_fn(n, d, |r, c| all[(r, c)]));
        // The grown store shares the base prefix segment-wise (the
        // online-drain layout) while the rebuild sees one dense chunk.
        let grown = base.append_rows(Matrix::from_fn(extra, d, |r, c| all[(n + r, c)]));
        // Queries from a shifted (OOD-ish) distribution: training side for
        // RoarGraph, wiring context for inserts, and the test panel.
        let mut qr = rng.fork(2);
        let qgen = |rows: usize, qr: &mut Rng| {
            Matrix::from_fn(rows, d, |_, c| qr.normal() + if c == 0 { 1.5 } else { 0.0 })
        };
        let train = qgen(64, &mut qr);
        let recent = qgen(16, &mut qr);
        let panel = qgen(24, &mut qr);
        let ctx = InsertContext { recent_queries: Some(&recent) };
        // Generous search params: reachability/mapping bugs still collapse
        // recall, while benign approximate-vs-approximate noise does not.
        let params = SearchParams { ef: 256, nprobe: 16 };

        let build = |which: usize, keys: KeyStore| -> Box<dyn VectorIndex> {
            match which {
                0 => Box::new(FlatIndex::new(keys)),
                1 => Box::new(IvfIndex::build(keys, Some(16), 5)),
                2 => Box::new(HnswIndex::build(keys, HnswParams::default())),
                _ => Box::new(RoarGraph::build(keys, &train, RoarParams::default())),
            }
        };
        for which in 0..4usize {
            let mut inserted = build(which, base.clone());
            prop_assert!(
                inserted.insert_batch(grown.clone(), n..total, &ctx),
                "index {which}: insert_batch refused"
            );
            prop_assert!(inserted.len() == total, "index {which}: wrong len after insert");
            let rebuilt = build(which, KeyStore::from_arc(all.clone()));
            let (mut rec_ins, mut rec_reb) = (0.0f32, 0.0f32);
            for qi in 0..panel.rows() {
                let q = panel.row(qi);
                let truth = exact_topk(&all, q, 10);
                rec_ins += inserted.search(q, 10, &params).recall_against(&truth);
                rec_reb += rebuilt.search(q, 10, &params).recall_against(&truth);
            }
            rec_ins /= panel.rows() as f32;
            rec_reb /= panel.rows() as f32;
            prop_assert!(
                rec_ins >= rec_reb - 0.05,
                "{}: insert recall {rec_ins} more than 0.05 below rebuild {rec_reb}",
                inserted.name()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_remove_insert_roundtrip_within_epsilon_and_no_tombstones_returned() {
    // The deletion contract, for every index family: evicting a subset and
    // then folding in a fresh batch must (a) never return a tombstoned id
    // from any search, and (b) retrieve over the live set within ε of a
    // from-scratch rebuild on exactly the live vectors.
    check("evict+reinsert ~ rebuild", 5, |rng| {
        let n = 128 + rng.below(96);
        let extra = 24 + rng.below(24);
        let d = [8usize, 16][rng.below(2)];
        let total = n + extra;
        let all = {
            let mut r = rng.fork(1);
            Arc::new(Matrix::from_fn(total, d, |_, _| r.normal()))
        };
        let base = KeyStore::from_matrix(Matrix::from_fn(n, d, |r, c| all[(r, c)]));
        let grown = base.append_rows(Matrix::from_fn(extra, d, |r, c| all[(n + r, c)]));
        // Evict ~1/6 of the base (below the rebuild ratio, so the pure
        // tombstone + re-link path is what gets exercised).
        let mut rr = rng.fork(3);
        let removed: Vec<u32> =
            rr.sample_indices(n, n / 6).into_iter().map(|i| i as u32).collect();
        let is_removed = |id: u32| removed.contains(&id);
        let live: Vec<u32> = (0..total as u32).filter(|&i| !is_removed(i)).collect();

        let mut qr = rng.fork(2);
        let qgen = |rows: usize, qr: &mut Rng| {
            Matrix::from_fn(rows, d, |_, c| qr.normal() + if c == 0 { 1.5 } else { 0.0 })
        };
        let train = qgen(64, &mut qr);
        let recent = qgen(16, &mut qr);
        let panel = qgen(16, &mut qr);
        let ctx = InsertContext { recent_queries: Some(&recent) };
        let params = SearchParams { ef: 256, nprobe: 16 };

        // Exact top-10 over the live set only.
        let live_truth = |q: &[f32]| -> Vec<u32> {
            let mut scored: Vec<(f32, u32)> = live
                .iter()
                .map(|&i| (retrieval_attention::tensor::dot(q, all.row(i as usize)), i))
                .collect();
            scored.sort_by(|a, b| b.0.total_cmp(&a.0));
            scored.into_iter().take(10).map(|(_, i)| i).collect()
        };

        let build = |which: usize, keys: KeyStore, train: &Matrix| -> Box<dyn VectorIndex> {
            match which {
                0 => Box::new(FlatIndex::new(keys)),
                1 => Box::new(IvfIndex::build(keys, Some(16), 5)),
                2 => Box::new(HnswIndex::build(keys, HnswParams::default())),
                _ => Box::new(RoarGraph::build(keys, train, RoarParams::default())),
            }
        };
        // Fresh rebuild over exactly the live vectors (compacted dense
        // ids; map back through `live` for comparison).
        let live_matrix =
            Matrix::from_fn(live.len(), d, |r, c| all[(live[r] as usize, c)]);
        for which in 0..4usize {
            let mut idx = build(which, base.clone(), &train);
            prop_assert!(idx.supports_remove(), "index {which} must support removal");
            prop_assert!(idx.remove_batch(&removed), "index {which}: remove refused");
            prop_assert!(
                idx.tombstones() == removed.len(),
                "index {which}: tombstone count {} != {}",
                idx.tombstones(),
                removed.len()
            );
            prop_assert!(
                idx.insert_batch(grown.clone(), n..total, &ctx),
                "index {which}: reinsert refused"
            );
            prop_assert!(
                idx.live_len() == total - removed.len(),
                "index {which}: live length wrong after evict+reinsert"
            );
            let rebuilt = build(which, KeyStore::from_matrix(live_matrix.clone()), &train);
            let (mut rec_rt, mut rec_reb) = (0.0f32, 0.0f32);
            for qi in 0..panel.rows() {
                let q = panel.row(qi);
                let truth = live_truth(q);
                let got = idx.search(q, 10, &params);
                // (a) no tombstoned id is ever returned — by any family,
                // under a generous beam.
                for id in &got.ids {
                    prop_assert!(!is_removed(*id), "{}: tombstoned id {id} returned", idx.name());
                }
                rec_rt += got.recall_against(&truth);
                let reb = rebuilt.search(q, 10, &params);
                let mapped: Vec<u32> = reb.ids.iter().map(|&c| live[c as usize]).collect();
                let hit = mapped.iter().filter(|id| truth.contains(id)).count();
                rec_reb += hit as f32 / truth.len().max(1) as f32;
            }
            rec_rt /= panel.rows() as f32;
            rec_reb /= panel.rows() as f32;
            // (b) ε-of-rebuild: the tombstone + re-link path must not
            // collapse recall relative to a compacted fresh build.
            prop_assert!(
                rec_rt >= rec_reb - 0.1,
                "{}: evict+reinsert recall {rec_rt} more than 0.1 below rebuild {rec_reb}",
                idx.name()
            );
            // Exhaustive sweep: even asking for everything never surfaces
            // a tombstone.
            let sweep =
                idx.search(&vec![0.0f32; d], total, &SearchParams { ef: total, nprobe: 64 });
            for id in &sweep.ids {
                prop_assert!(!is_removed(*id), "{}: sweep returned tombstoned {id}", idx.name());
            }
            prop_assert!(
                sweep.ids.len() <= total - removed.len(),
                "{}: sweep returned more than the live set",
                idx.name()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_remap_roundtrip_preserves_live_results_all_families() {
    // The reclamation contract, for every index family: tombstone a
    // subset, remap through a compaction plan (dense ids renumbered, the
    // store physically shrunk), and require that (a) the dense space
    // compacted exactly (len == live, zero tombstones), (b) no stale or
    // out-of-range id is ever returned, and (c) search results over the
    // surviving rows are the renumbered pre-remap results — exactly for
    // the list-based families (flat/IVF), within recall tolerance for
    // the graphs (whose dead transit shortcuts vanish) — and (d) inserts
    // keep working in the compacted space.
    check("remap round-trip", 5, |rng| {
        let n = 128 + rng.below(128);
        let d = [8usize, 16][rng.below(2)];
        let all = {
            let mut r = rng.fork(1);
            Arc::new(Matrix::from_fn(n, d, |_, _| r.normal()))
        };
        let base = KeyStore::from_arc(all.clone());
        let mut rr = rng.fork(3);
        let mut removed: Vec<u32> =
            rr.sample_indices(n, n / 5).into_iter().map(|i| i as u32).collect();
        removed.sort_unstable();
        removed.dedup();
        // The production planner (what `Job::Compact` uses).
        let Some((plan, keep)) = RemapPlan::from_dead(&removed, &base, 1) else {
            return Err("planner refused a non-empty drop set".into());
        };
        prop_assert!(
            keep == (0..n as u32).filter(|i| !removed.contains(i)).collect::<Vec<u32>>(),
            "planner keep-set diverged"
        );

        let mut qr = rng.fork(2);
        let qgen = |rows: usize, qr: &mut Rng| {
            Matrix::from_fn(rows, d, |_, c| qr.normal() + if c == 0 { 1.5 } else { 0.0 })
        };
        let train = qgen(64, &mut qr);
        let panel = qgen(12, &mut qr);
        let params = SearchParams { ef: 256, nprobe: 16 };

        let build = |which: usize, keys: KeyStore| -> Box<dyn VectorIndex> {
            match which {
                0 => Box::new(FlatIndex::new(keys)),
                1 => Box::new(IvfIndex::build(keys, Some(16), 5)),
                2 => Box::new(HnswIndex::build(keys, HnswParams::default())),
                _ => Box::new(RoarGraph::build(keys, &train, RoarParams::default())),
            }
        };
        for which in 0..4usize {
            let mut idx = build(which, base.clone());
            prop_assert!(idx.supports_remap(), "index {which} must support remap");
            prop_assert!(idx.remove_batch(&removed), "index {which}: remove refused");
            prop_assert!(
                idx.dead_ids() == removed,
                "index {which}: dead_ids diverged from the remove set"
            );
            let pre: Vec<Vec<u32>> =
                (0..panel.rows()).map(|qi| idx.search(panel.row(qi), 10, &params).ids).collect();
            prop_assert!(idx.remap_dense(&plan), "index {which}: remap refused");
            prop_assert!(idx.len() == keep.len(), "index {which}: len != live after remap");
            prop_assert!(idx.tombstones() == 0, "index {which}: tombstones survived remap");
            prop_assert!(idx.dead_ids().is_empty(), "index {which}: dead ids survived remap");
            for (qi, old_ids) in pre.iter().enumerate() {
                let post = idx.search(panel.row(qi), 10, &params).ids;
                for &id in &post {
                    prop_assert!(
                        (id as usize) < keep.len(),
                        "{}: post-remap id {id} out of range",
                        idx.name()
                    );
                }
                // Pre-remap results are live by construction; renumber them.
                let expect: Vec<u32> = old_ids
                    .iter()
                    .map(|&o| {
                        prop_assert!(
                            plan.old_to_new[o as usize] != RemapPlan::DROPPED,
                            "pre-remap search returned a tombstone"
                        );
                        Ok(plan.old_to_new[o as usize])
                    })
                    .collect::<Result<_, _>>()?;
                match which {
                    // Exact structures: identical results, renumbered.
                    0 | 1 => prop_assert!(
                        post == expect,
                        "{}: remap changed exact results: {post:?} vs {expect:?}",
                        idx.name()
                    ),
                    // Graphs: near-identical (dead transit nodes vanished).
                    _ => {
                        let hits = post.iter().filter(|id| expect.contains(id)).count();
                        prop_assert!(
                            hits * 10 >= expect.len() * 8,
                            "{}: remap lost results: {hits}/{} overlap",
                            idx.name(),
                            expect.len()
                        );
                    }
                }
            }
            // (d) the insert path still works against the compacted store.
            let extra = Matrix::from_fn(8, d, |r, c| (r as f32 - c as f32) * 0.3);
            let grown = plan.store.append_rows(extra);
            let total = grown.rows();
            prop_assert!(
                idx.insert_batch(grown, keep.len()..total, &InsertContext::none()),
                "index {which}: post-remap insert refused"
            );
            prop_assert!(idx.len() == total, "index {which}: wrong len after post-remap insert");
        }
        Ok(())
    });
}

#[test]
fn prop_kernel_simd_and_scalar_agree_bitwise_on_f32() {
    // The dispatch contract: whichever backend `kernel::active()` picked
    // (AVX2+FMA, NEON, or scalar — force the latter with
    // `RA_KERNEL=scalar`), every f32 score is bit-for-bit the scalar
    // reference. Switching kernels may change latency, never results.
    check("simd == scalar bits", 25, |rng| {
        let n = 1 + rng.below(400);
        let mut r = rng.fork(1);
        let a: Vec<f32> = (0..n).map(|_| r.normal() * 2.0).collect();
        let b: Vec<f32> = (0..n).map(|_| r.normal() * 2.0).collect();
        let (d, d_ref) = (kernel::dot(&a, &b), kernel::scalar::dot(&a, &b));
        prop_assert!(
            d.to_bits() == d_ref.to_bits(),
            "dot bits diverged under {:?}: {d} vs {d_ref}",
            kernel::active()
        );
        let (l, l_ref) = (kernel::l2_sq(&a, &b), kernel::scalar::l2_sq(&a, &b));
        prop_assert!(
            l.to_bits() == l_ref.to_bits(),
            "l2_sq bits diverged under {:?}: {l} vs {l_ref}",
            kernel::active()
        );
        // The batch entry points are elementwise-identical to the row
        // forms (so batching in the index hot loops is latency-only too).
        let cols = 1 + rng.below(96);
        let rows_n = 1 + rng.below(20);
        let mut r2 = rng.fork(2);
        let q: Vec<f32> = (0..cols).map(|_| r2.normal()).collect();
        let rows: Vec<f32> = (0..cols * rows_n).map(|_| r2.normal()).collect();
        let mut batched = Vec::new();
        kernel::dot_rows(&q, &rows, cols, &mut batched);
        prop_assert!(batched.len() == rows_n, "dot_rows row count");
        let mut l2b = Vec::new();
        kernel::l2_rows(&q, &rows, cols, &mut l2b);
        for i in 0..rows_n {
            let row = &rows[i * cols..(i + 1) * cols];
            prop_assert!(
                batched[i].to_bits() == kernel::scalar::dot(&q, row).to_bits(),
                "dot_rows row {i} diverged"
            );
            prop_assert!(
                l2b[i].to_bits() == kernel::scalar::l2_sq(&q, row).to_bits(),
                "l2_rows row {i} diverged"
            );
        }
        let ids: Vec<u32> = (0..rows_n as u32).rev().collect();
        let mut gathered = Vec::new();
        kernel::dot_gather(&q, &rows, cols, &ids, &mut gathered);
        for (j, &id) in ids.iter().enumerate() {
            prop_assert!(
                gathered[j].to_bits() == batched[id as usize].to_bits(),
                "dot_gather id {id} diverged"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_quantized_recall_within_bound_all_families() {
    // The quantized-scan-tier contract: for every index family, ranking
    // candidates against the int8/fp16 mirror (with the default exact
    // re-rank pool of 2×k) keeps recall@k at ≥ 0.95 of what the same
    // family achieves scoring f32 — quantization error must be confined
    // to candidate ordering beyond the re-rank pool.
    check("quant recall ≥ 0.95 × f32", 4, |rng| {
        let n = 256 + rng.below(256);
        let d = [16usize, 32, 64][rng.below(3)];
        let keys = {
            let mut r = rng.fork(1);
            Matrix::from_fn(n, d, |_, _| r.normal())
        };
        let mut qr = rng.fork(2);
        let qgen = |rows: usize, qr: &mut Rng| {
            Matrix::from_fn(rows, d, |_, c| qr.normal() + if c == 0 { 1.5 } else { 0.0 })
        };
        let train = qgen(64, &mut qr);
        let panel = qgen(12, &mut qr);
        let params = SearchParams { ef: 256, nprobe: 16 };
        let k = 10;
        let build = |which: usize, keys: KeyStore| -> Box<dyn VectorIndex> {
            match which {
                0 => Box::new(FlatIndex::new(keys)),
                1 => Box::new(IvfIndex::build(keys, Some(16), 5)),
                2 => Box::new(HnswIndex::build(keys, HnswParams::default())),
                _ => Box::new(RoarGraph::build(keys, &train, RoarParams::default())),
            }
        };
        let f32_store = KeyStore::from_matrix(keys.clone());
        for mode in [QuantMode::Fp16, QuantMode::Int8] {
            let qstore = KeyStore::from_matrix(keys.clone()).with_quant(mode);
            prop_assert!(qstore.is_quantized(), "{mode:?}: store must carry the tier");
            for which in 0..4usize {
                let exact_idx = build(which, f32_store.clone());
                let qidx = build(which, qstore.clone());
                prop_assert!(
                    qidx.scan_quantized() && !exact_idx.scan_quantized(),
                    "index {which}: scan_quantized must reflect the store"
                );
                let (mut rec_f, mut rec_q) = (0.0f32, 0.0f32);
                for qi in 0..panel.rows() {
                    let q = panel.row(qi);
                    let truth = exact_topk(&keys, q, k);
                    rec_f += exact_idx.search(q, k, &params).recall_against(&truth);
                    let got = search_rerank(qidx.as_ref(), q, k, 2, &params);
                    // Re-ranked scores are exact f32 inner products.
                    for (&id, &s) in got.ids.iter().zip(got.scores.iter()) {
                        let expect =
                            retrieval_attention::tensor::dot(q, keys.row(id as usize));
                        prop_assert!(
                            (s - expect).abs() < 1e-4,
                            "{}: rerank score not exact: {s} vs {expect}",
                            qidx.name()
                        );
                    }
                    rec_q += got.recall_against(&truth);
                }
                rec_f /= panel.rows() as f32;
                rec_q /= panel.rows() as f32;
                prop_assert!(
                    rec_q >= 0.95 * rec_f - 1e-6,
                    "{} under {mode:?}: quantized recall {rec_q} below 0.95 × f32 recall {rec_f}",
                    qidx.name()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantized_mirrors_survive_reclamation_remap() {
    // The storage-engine contract for the quantized tier: a reclamation
    // epoch (tombstone → RemapPlan → remap_dense) must carry the mirrors
    // through — the compacted store stays quantized, searches still rank
    // against it, and the exact re-rank still returns true f32 scores.
    check("quant mirrors survive remap", 5, |rng| {
        let n = 128 + rng.below(128);
        let d = [8usize, 16, 32][rng.below(3)];
        let keys = {
            let mut r = rng.fork(1);
            Matrix::from_fn(n, d, |_, _| r.normal())
        };
        // Several segments (the smaller append does not tail-merge into
        // the larger prefix), so the remap exercises both shared-intact
        // and gathered chunks.
        let split = (3 * n) / 4;
        let mut store = KeyStore::from_matrix(Matrix::from_fn(split, d, |r, c| keys[(r, c)]))
            .with_quant(QuantMode::Int8);
        store = store.append_rows(Matrix::from_fn(n - split, d, |r, c| keys[(split + r, c)]));
        prop_assert!(store.segment_count() >= 2, "setup needs several segments");
        prop_assert!(
            store.mirrored_segments() == store.segment_count(),
            "append must keep every chunk mirrored"
        );
        let mut rr = rng.fork(3);
        let mut removed: Vec<u32> =
            rr.sample_indices(n, n / 5).into_iter().map(|i| i as u32).collect();
        removed.sort_unstable();
        removed.dedup();
        let mut idx = FlatIndex::new(store.clone());
        prop_assert!(idx.remove_batch(&removed), "remove refused");
        let Some((plan, keep)) = RemapPlan::from_dead(&removed, &store, 1) else {
            return Err("planner refused".into());
        };
        prop_assert!(plan.store.is_quantized(), "compacted store lost the quantized tier");
        prop_assert!(
            plan.store.mirrored_segments() == plan.store.segment_count(),
            "compaction must keep every chunk mirrored"
        );
        prop_assert!(idx.remap_dense(&plan), "remap refused");
        prop_assert!(idx.scan_quantized(), "index lost the quantized tier across remap");
        // Post-remap searches (with exact re-rank) agree with exact top-k
        // over the surviving rows.
        let mut qr = rng.fork(2);
        let q: Vec<f32> = (0..d).map(|_| qr.normal()).collect();
        let survivors = Matrix::from_fn(keep.len(), d, |r, c| keys[(keep[r] as usize, c)]);
        let truth = exact_topk(&survivors, &q, 10);
        let got = search_rerank(&idx, &q, 10, 2, &SearchParams::default());
        let hits = got.ids.iter().filter(|id| truth.contains(id)).count();
        prop_assert!(
            hits * 10 >= truth.len() * 9,
            "post-remap quantized search lost recall: {hits}/{}",
            truth.len()
        );
        // And the tier keeps following the store through further appends.
        let grown = plan.store.append_rows(Matrix::from_fn(8, d, |r, c| (r + c) as f32 * 0.1));
        prop_assert!(
            grown.mirrored_segments() == grown.segment_count(),
            "post-remap append lost a mirror"
        );
        Ok(())
    });
}

#[test]
fn prop_static_pattern_partitions_tokens() {
    use retrieval_attention::kvcache::{StaticPattern, TieredKvCache};
    check("tier partition", 20, |rng| {
        let sink = rng.below(64);
        let window = 1 + rng.below(128);
        let prefill = 1 + rng.below(1000);
        let decode = rng.below(50);
        let d = 4;
        let mut cache = TieredKvCache::new(d, StaticPattern { sink, window });
        let mut r = rng.fork(1);
        for _ in 0..prefill {
            let k: Vec<f32> = (0..d).map(|_| r.normal()).collect();
            cache.append(&k, &k);
        }
        cache.seal_prefill();
        for _ in 0..decode {
            let k: Vec<f32> = (0..d).map(|_| r.normal()).collect();
            cache.append(&k, &k);
        }
        let mut all: Vec<u32> = cache.device_ids();
        all.extend(cache.indexed_ids());
        all.extend(cache.overflow_ids());
        all.sort_unstable();
        let expect: Vec<u32> = (0..(prefill + decode) as u32).collect();
        prop_assert!(
            all == expect,
            "tiers must partition exactly once (sink={sink} window={window} n={prefill}+{decode})"
        );
        Ok(())
    });
}
