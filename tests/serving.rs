//! Integration tests over the serving layer: replica scheduling,
//! continuous batching, routing, backpressure, and the TCP front-end.
//!
//! Always executed: engines fall back to the runtime's native backend when
//! PJRT artifacts are absent, so these tests can no longer silently pass
//! without running the serving stack.

use retrieval_attention::config::{Method, ServeConfig};
use retrieval_attention::coordinator::{
    collect, collect_deadline, router::Router, Event, Replica, Request,
};
use retrieval_attention::kvcache::StaticPattern;
use retrieval_attention::server::{Client, Server};
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::tasks;
use std::sync::Arc;

fn cfg(method: Method) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = method;
    cfg.pattern = StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg
}

#[test]
fn replica_serves_one_request() {
    let replica = Replica::spawn(cfg(Method::RetrievalAttention));
    let mut rng = Rng::seed_from(1);
    let s = tasks::passkey(&mut rng, 700, 0.3);
    let rx =
        replica.submit(Request { id: 1, prompt: s.prompt.clone(), max_tokens: 2, session: None });
    let (tokens, m) = collect(&rx).unwrap();
    assert_eq!(tokens.len(), 2);
    assert!(s.passed(&tokens), "wrong answer: {tokens:?} want {:?}", s.expect);
    assert_eq!(m.prompt_tokens, 700);
    assert!(m.prefill_s > 0.0 && m.ttft_s >= m.prefill_s);
}

#[test]
fn continuous_batching_interleaves_sessions() {
    let replica = Replica::spawn(cfg(Method::Flat));
    let mut rng = Rng::seed_from(2);
    let samples: Vec<_> = (0..3).map(|_| tasks::passkey(&mut rng, 600, 0.5)).collect();
    let rxs: Vec<_> = samples
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let req =
                Request { id: i as u64, prompt: s.prompt.clone(), max_tokens: 2, session: None };
            replica.submit(req)
        })
        .collect();
    for (rx, s) in rxs.iter().zip(samples.iter()) {
        let (tokens, _) = collect(rx).unwrap();
        assert!(s.passed(&tokens));
    }
    assert_eq!(replica.outstanding(), 0, "all requests retired");
}

#[test]
fn outstanding_counts_resident_sessions_exactly_once() {
    // Exactly-once slot accounting: a session scheduled across many waves
    // is still ONE outstanding request, and the count drops only at
    // retirement. `max_batch = 1` forces the other submissions to queue so
    // the queue-depth gauge is exercised too.
    let mut c = cfg(Method::Flat);
    c.scheduler.max_batch = 1;
    let replica = Replica::spawn(c);
    let mut rng = Rng::seed_from(13);
    let samples: Vec<_> = (0..3).map(|_| tasks::passkey(&mut rng, 600, 0.5)).collect();
    let rxs: Vec<_> = samples
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let req =
                Request { id: i as u64, prompt: s.prompt.clone(), max_tokens: 4, session: None };
            replica.submit(req)
        })
        .collect();
    // Slots are entered in submit, before the worker sees the job: all
    // three are in flight now, each counted once (not once per wave).
    assert_eq!(replica.outstanding(), 3, "one slot per request, entered at submit");
    let (first_tokens, m0) = collect(&rxs[0]).unwrap();
    assert!(samples[0].passed(&first_tokens));
    // Retirement precedes the terminal event, so by the time collect()
    // returns the first slot is already released.
    assert!(replica.outstanding() <= 2, "retired request still counted");
    // With max_batch = 1 the later submissions queued behind the first.
    assert!(m0.queue_depth_peak >= 1, "queued requests invisible to the gauge");
    for (rx, s) in rxs.iter().zip(samples.iter()).skip(1) {
        let (tokens, _) = collect(rx).unwrap();
        assert!(s.passed(&tokens));
    }
    assert_eq!(replica.outstanding(), 0, "slots must drain to zero");
    assert_eq!(replica.queue_depth(), 0, "queue gauge must drain to zero");
}

#[test]
fn router_balances_load() {
    let router = Router::spawn(cfg(Method::StreamingLlm), 2);
    assert_eq!(router.replica_count(), 2);
    let mut rng = Rng::seed_from(3);
    let rxs: Vec<_> = (0..4)
        .map(|_| {
            let s = tasks::passkey(&mut rng, 400, 0.9);
            router.submit(Request {
                id: router.next_request_id(),
                prompt: s.prompt,
                max_tokens: 1,
                session: None,
            })
        })
        .collect();
    for rx in &rxs {
        let (tokens, _) = collect(rx).unwrap();
        assert_eq!(tokens.len(), 1);
    }
    assert_eq!(router.total_outstanding(), 0);
}

#[test]
fn tcp_roundtrip_with_streaming() {
    let router = Arc::new(Router::spawn(cfg(Method::RetrievalAttention), 1));
    let server = Server::start(router, "127.0.0.1:0").unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    let mut rng = Rng::seed_from(4);
    let s = tasks::passkey(&mut rng, 500, 0.4);
    let (tokens, done) = client.generate(&s.prompt, 2).unwrap();
    assert!(s.passed(&tokens), "wrong answer over TCP: {tokens:?}");
    assert!(done.req_f64("tpot_s").unwrap() >= 0.0);
    // Second request on the same connection.
    let s2 = tasks::passkey(&mut rng, 500, 0.8);
    let (tokens2, _) = client.generate(&s2.prompt, 2).unwrap();
    assert!(s2.passed(&tokens2));
}

#[test]
fn tcp_session_verbs_roundtrip() {
    use retrieval_attention::util::json::Value;
    let router = Arc::new(Router::spawn(cfg(Method::RetrievalAttention), 1));
    let server = Server::start(router, "127.0.0.1:0").unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    let mut rng = Rng::seed_from(12);
    let s = tasks::passkey(&mut rng, 500, 0.4);
    // Turn 1: open retains the session server-side.
    let (t1, _) = client.open_session(7, &s.prompt, 2).unwrap();
    assert!(s.passed(&t1), "turn 1 wrong over TCP: {t1:?}");
    // Turn 2: continue decode-extends without prefill (resident hit under
    // the default RAM budget).
    let (t2, done2) = client.continue_session(7, &[5, 1], 2).unwrap();
    assert_eq!(t2.len(), 2);
    assert_eq!(done2.get("resumed_from_disk").and_then(Value::as_bool), Some(false));
    assert!(done2.get("resume_s").and_then(Value::as_f64).is_some());
    // Close, then a further continue fails cleanly.
    let closed = client.close_session(7).unwrap();
    assert_eq!(closed.req_str("event").unwrap(), "done");
    assert!(client.continue_session(7, &[1], 1).is_err());
    // The connection still serves sessionless requests.
    let s2 = tasks::passkey(&mut rng, 500, 0.8);
    let (tokens, _) = client.generate(&s2.prompt, 2).unwrap();
    assert!(s2.passed(&tokens));
}

#[test]
fn vllm_like_admission_rejects_oom() {
    let mut c = cfg(Method::VllmLike);
    c.hw = "rtx4090".into(); // 24GB budget; induction weights tiny but the
                             // prompt below is small too — use a tiny budget
                             // via the localhost->rtx4090 contrast instead:
    let replica = Replica::spawn(c);
    // 600-token prompt: KV fits easily (induction-mini is tiny) => succeeds.
    let mut rng = Rng::seed_from(5);
    let s = tasks::passkey(&mut rng, 600, 0.5);
    let rx = replica.submit(Request { id: 1, prompt: s.prompt, max_tokens: 1, session: None });
    assert!(collect(&rx).is_ok(), "small vllm-like request must be admitted");
}

#[test]
fn truncate_and_fork_sessions() {
    // Session lifecycle over the deletion path: a fork decodes
    // independently, and truncation tombstones the dropped ids in every
    // head's index (chat rollback) while the session stays decodable.
    use retrieval_attention::model::Engine;
    let mut c = cfg(Method::RetrievalAttention);
    c.retrieval.maintenance.drain_watermark = 16;
    let eng = Engine::from_config(c).expect("engine init");
    let mut rng = Rng::seed_from(11);
    let s = tasks::passkey(&mut rng, 700, 0.2);
    let mut sess = eng.prefill(&s.prompt).unwrap();

    // Fork before decoding: both sessions must solve independently.
    let mut fork = eng.fork_session(&mut sess).unwrap();
    let (t1, _) = eng.generate(&mut sess, 2).unwrap();
    assert!(s.passed(&t1), "original failed: {t1:?}");
    let (t2, _) = eng.generate(&mut fork, 2).unwrap();
    assert!(s.passed(&t2), "fork failed: {t2:?}");
    // The fork's drains are its own: counters diverge independently.
    fork.shutdown_maintenance();

    // Truncate the original mid-conversation. Capture a to-be-dropped
    // key first so we can probe the index afterwards.
    let probe_key: Vec<f32> = sess.caches[0][0].key(500).to_vec();
    eng.truncate_session(&mut sess, 400).unwrap();
    assert_eq!(sess.len, 400);
    for caches in &sess.caches {
        for c in caches {
            assert_eq!(c.len(), 400, "cache not truncated");
            assert!(c.indexed_end() <= 400);
        }
    }
    // Dropped ids are tombstoned: nothing at or past the cut is ever
    // retrieved again, even when probed with a dropped token's own key.
    let out = sess.retrievers[0][0].retrieve(&probe_key, 64);
    assert!(
        out.ids.iter().all(|&id| (id as usize) < 400),
        "dropped id retrievable after truncate: {:?}",
        out.ids
    );
    assert!(sess.tombstone_ratio() > 0.0, "truncation must tombstone");
    // The truncated session keeps decoding without panicking.
    let out = eng.decode_step(&mut sess, 7).unwrap();
    assert!((out.token as usize) < eng.spec().vocab);
    assert_eq!(sess.len, 401);
    // Truncating to an invalid length is refused.
    assert!(eng.truncate_session(&mut sess, 0).is_err());
    assert!(eng.truncate_session(&mut sess, 10_000).is_err());
}

#[test]
fn collect_deadline_bounds_the_gap_not_the_generation() {
    // The deadline is per event GAP: a stream that keeps making progress
    // never times out, while one that stalls surfaces within one deadline
    // — and a dropped replica is a distinct, immediate error.
    let (tx, rx) = std::sync::mpsc::channel::<Event>();
    tx.send(Event::Token(1, 42)).unwrap();
    let err = collect_deadline(&rx, 50).expect_err("stalled stream must time out");
    assert!(
        err.to_string().contains("deadline exceeded"),
        "unexpected timeout shape: {err}"
    );
    drop(tx);
    let err = collect_deadline(&rx, 50).expect_err("dropped sender must fail");
    assert!(
        err.to_string().contains("replica dropped the request"),
        "unexpected disconnect shape: {err}"
    );
    // deadline_ms == 0 is plain blocking collect: terminal events pass
    // through untouched.
    let (tx, rx) = std::sync::mpsc::channel::<Event>();
    tx.send(Event::Failed(2, "boom".into())).unwrap();
    let err = collect_deadline(&rx, 0).expect_err("failure event must surface");
    assert!(err.to_string().contains("boom"), "{err}");
}

#[test]
fn client_deadline_surfaces_on_unresponsive_server() {
    // A server that accepts the connection but never answers: without a
    // deadline the client would block forever; with one it fails cleanly
    // and the error names the deadline, not a raw IO kind.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let hold = std::thread::spawn(move || {
        // Accept and hold the socket open, reading nothing, answering
        // nothing, until the client has given up.
        let (_stream, _) = listener.accept().unwrap();
        std::thread::sleep(std::time::Duration::from_secs(2));
    });
    let mut client = Client::connect(addr).unwrap();
    client.set_deadline(100).unwrap();
    let start = std::time::Instant::now();
    let err = client.generate(&[1, 2, 3], 1).expect_err("unanswered request must time out");
    assert!(
        err.to_string().contains("client deadline exceeded"),
        "unexpected error shape: {err}"
    );
    assert!(
        start.elapsed() < std::time::Duration::from_millis(1500),
        "deadline did not bound the wait"
    );
    drop(client);
    let _ = hold.join();
}

#[test]
fn bad_request_fails_gracefully() {
    let replica = Replica::spawn(cfg(Method::RetrievalAttention));
    // Empty prompt must fail, not crash the worker.
    let rx = replica.submit(Request { id: 9, prompt: vec![], max_tokens: 1, session: None });
    match rx.recv().unwrap() {
        Event::Failed(id, msg) => {
            assert_eq!(id, 9);
            assert!(msg.contains("empty"), "unexpected message: {msg}");
        }
        other => panic!("expected failure, got {other:?}"),
    }
    // The worker must still serve subsequent requests.
    let mut rng = Rng::seed_from(6);
    let s = tasks::passkey(&mut rng, 400, 0.2);
    let rx =
        replica.submit(Request { id: 10, prompt: s.prompt.clone(), max_tokens: 2, session: None });
    let (tokens, _) = collect(&rx).unwrap();
    assert!(s.passed(&tokens));
}
