//! The fault-injection matrix (feature `failpoints`, `make test-faults`):
//! every instrumented site in [`failpoint::SITES`] is driven here, and a
//! guard test fails if a site is ever added without coverage.
//!
//! The contract under test, per docs/robustness.md:
//!
//! * An injected IO error on the **park path** fails exactly the one
//!   request (with the bounded-retry story in the error text), leaves no
//!   temp/partial litter in the spill dir, and the replica keeps serving.
//! * A **transient** fault (one blip within the retry budget) is absorbed
//!   invisibly on the write side, and on the resume side either retries
//!   inside the turn (`spill.read`) or fails the turn while keeping the
//!   parked snapshot restorable (`session.restore`).
//! * A failure **inside the restore parse** (`codec.restore`) is
//!   corruption: quarantine, clean error, definitive miss afterwards.
//! * A fault in a **wave slot** (`wave.decode`, error or panic) fails
//!   that slot only; survivors' tokens stay bit-identical to a solo
//!   decode.
//! * A fault at a **maintenance publish point** yields the documented
//!   `ok: false` clean-retry completion with nothing mutated, and the
//!   resubmitted job completes — including when the fault is a panic
//!   (containment synthesizes the completion).
//! * A **worker-thread kill** (`worker.step` panic) is supervised: the
//!   next submit respawns the worker, parked sessions come back through
//!   the durable spill tier, and the continuation is token-identical to
//!   a never-crashed control. With the respawn budget at zero the replica
//!   fails explicitly instead.
//!
//! The failpoint registry is process-global, so this suite must run
//! serialized: `cargo test --features failpoints --test fault_injection
//! -- --test-threads=1` (the `make test-faults` target).
#![cfg(feature = "failpoints")]

use retrieval_attention::baselines::{build_retriever, HostRetriever, RetrieverInputs};
use retrieval_attention::config::{Method, RetrievalConfig, ServeConfig};
use retrieval_attention::coordinator::{collect, Replica, Request, SessionMode, SessionSpec};
use retrieval_attention::index::KeyStore;
use retrieval_attention::kvcache::StaticPattern;
use retrieval_attention::model::maintain::{
    CompactJob, DoneKind, DrainJob, EvictJob, Job, MaintenanceState,
};
use retrieval_attention::model::{Engine, WaveItem};
use retrieval_attention::tensor::Matrix;
use retrieval_attention::util::failpoint::{self, FailAction};
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::tasks;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn base_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = Method::RetrievalAttention;
    cfg.pattern = StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.ef = 64;
    // Deterministic decodes: inline maintenance, watermark high enough
    // that the short turns below never drain mid-comparison.
    cfg.retrieval.maintenance.async_worker = false;
    cfg.retrieval.maintenance.drain_watermark = 1024;
    cfg
}

/// Park-every-turn into a durable (crash-survivable) spill dir.
fn durable_cfg(dir: &Path) -> ServeConfig {
    let mut cfg = base_cfg();
    cfg.serving.session_cache.max_resident_bytes = 0;
    cfg.serving.session_cache.spill_dir = dir.to_string_lossy().into_owned();
    cfg.serving.session_cache.ephemeral_spill = false;
    cfg
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ra-faults-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn turn(
    id: u64,
    session_id: u64,
    mode: SessionMode,
    prompt: Vec<u32>,
    max_tokens: usize,
) -> Request {
    Request { id, prompt, max_tokens, session: Some(SessionSpec { session_id, mode }) }
}

/// Guard: a new failpoint site cannot land without a degradation story in
/// this matrix (and its row in docs/robustness.md).
#[test]
fn every_registered_site_is_covered_by_this_matrix() {
    let covered = [
        "spill.write",
        "spill.commit",
        "spill.read",
        "codec.snapshot",
        "codec.restore",
        "maint.drain.publish",
        "maint.compact.publish",
        "wave.decode",
        "session.restore",
        "worker.step",
    ];
    for site in failpoint::SITES {
        assert!(
            covered.contains(site),
            "failpoint `{site}` has no fault-injection coverage; extend \
             tests/fault_injection.rs and docs/robustness.md"
        );
    }
}

#[test]
fn park_path_faults_fail_one_request_and_leave_no_litter() {
    let dir = tmpdir("park");
    let rep = Replica::spawn(durable_cfg(&dir));
    let mut rng = Rng::seed_from(101);
    // Hard-down faults at each park-path site: the park retries its
    // bounded budget (1 + spill_retries = 3 attempts), then fails exactly
    // this request, with no temp or partial file left behind.
    for (i, site) in ["spill.write", "spill.commit", "codec.snapshot"].into_iter().enumerate() {
        failpoint::reset();
        failpoint::arm(site, FailAction::Error { after: 0, times: u64::MAX });
        let s = tasks::passkey(&mut rng, 400, 0.3);
        let sid = 10 + i as u64;
        let rx = rep.submit(turn(sid, sid, SessionMode::Open, s.prompt.clone(), 2));
        let err =
            collect(&rx).expect_err("a hard-down park path must fail the session's request");
        let msg = err.to_string();
        assert!(msg.contains(&format!("failpoint `{site}`")), "{site}: {msg}");
        assert!(msg.contains("attempt(s)"), "{site}: retry story lost: {msg}");
        assert_eq!(failpoint::hits(site), 3, "{site}: retry budget must be bounded");
        let litter: Vec<String> = std::fs::read_dir(&dir)
            .map(|rd| {
                rd.flatten().map(|e| e.file_name().to_string_lossy().into_owned()).collect()
            })
            .unwrap_or_default();
        assert!(litter.is_empty(), "{site}: failed park left litter: {litter:?}");
        // The failed session was never registered: a continue is a clean
        // unknown-session error, not a half-parked resume.
        failpoint::reset();
        let rx = rep.submit(turn(100 + sid, sid, SessionMode::Continue, vec![1, 2], 1));
        let err = collect(&rx).expect_err("failed park must not register the session");
        assert!(err.to_string().contains("unknown session"), "{site}: {err}");
    }
    // The replica survived all three storms: a full park/resume cycle.
    failpoint::reset();
    let s = tasks::passkey(&mut rng, 400, 0.4);
    let rx = rep.submit(turn(90, 99, SessionMode::Open, s.prompt.clone(), 2));
    let (tokens, _) = collect(&rx).expect("replica must keep serving after injected faults");
    assert!(s.passed(&tokens));
    let rx = rep.submit(turn(91, 99, SessionMode::Continue, vec![3, 1, 4], 2));
    let (_, m) = collect(&rx).expect("post-fault continue");
    assert!(m.resumed_from_disk);
    drop(rep);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn transient_spill_write_fault_is_absorbed_by_retry() {
    let dir = tmpdir("transient-write");
    let rep = Replica::spawn(durable_cfg(&dir));
    failpoint::reset();
    failpoint::arm("spill.write", FailAction::Error { after: 0, times: 1 });
    let mut rng = Rng::seed_from(103);
    let s = tasks::passkey(&mut rng, 400, 0.3);
    let rx = rep.submit(turn(1, 1, SessionMode::Open, s.prompt.clone(), 2));
    let (tokens, _) = collect(&rx).expect("one blip within the retry budget must be invisible");
    assert!(s.passed(&tokens));
    assert_eq!(failpoint::hits("spill.write"), 2, "fail once, succeed on the retry");
    assert!(dir.join("session-1.ras").exists(), "retried park must publish");
    failpoint::reset();
    let rx = rep.submit(turn(2, 1, SessionMode::Continue, vec![5, 1], 2));
    let (_, m) = collect(&rx).expect("continue after retried park");
    assert!(m.resumed_from_disk);
    drop(rep);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn restore_faults_transient_vs_corruption_semantics() {
    let dir = tmpdir("restore");
    let rep = Replica::spawn(durable_cfg(&dir));
    let mut rng = Rng::seed_from(105);
    let s = tasks::passkey(&mut rng, 400, 0.3);
    let rx = rep.submit(turn(1, 3, SessionMode::Open, s.prompt.clone(), 2));
    collect(&rx).expect("open turn");

    // (a) `spill.read` — transient open blip, retried INSIDE the resume:
    // the turn itself never sees it.
    failpoint::reset();
    failpoint::arm("spill.read", FailAction::Error { after: 0, times: 1 });
    let rx = rep.submit(turn(2, 3, SessionMode::Continue, vec![5, 1], 2));
    let (_, m) = collect(&rx).expect("open blip must be retried inside the resume");
    assert!(m.resumed_from_disk);
    assert_eq!(failpoint::hits("spill.read"), 2);

    // (b) `session.restore` — the whole resume step fails as transient:
    // the turn fails, but the parked snapshot stays registered and the
    // retried turn succeeds (the caller-retries contract).
    failpoint::reset();
    failpoint::arm("session.restore", FailAction::Error { after: 0, times: 1 });
    let rx = rep.submit(turn(3, 3, SessionMode::Continue, vec![5, 1], 2));
    let err = collect(&rx).expect_err("injected resume fault must fail the turn");
    assert!(err.to_string().contains("failpoint `session.restore`"), "{err}");
    assert!(
        dir.join("session-3.ras").exists(),
        "a transient resume fault must not consume the snapshot"
    );
    failpoint::reset();
    let rx = rep.submit(turn(4, 3, SessionMode::Continue, vec![5, 1], 2));
    let (_, m) = collect(&rx).expect("retried turn must resume");
    assert!(m.resumed_from_disk);

    // (c) `codec.restore` — a failure inside the parse is corruption:
    // quarantine, clean error, and a definitive miss afterwards.
    failpoint::reset();
    failpoint::arm("codec.restore", FailAction::Error { after: 0, times: u64::MAX });
    let rx = rep.submit(turn(5, 3, SessionMode::Continue, vec![5, 1], 2));
    let err = collect(&rx).expect_err("parse-level fault must fail the turn");
    assert!(err.to_string().contains("quarantined"), "{err}");
    assert!(!dir.join("session-3.ras").exists(), "corrupt snapshot left under live name");
    assert!(dir.join("session-3.ras.corrupt").exists(), "quarantine file missing");
    failpoint::reset();
    let rx = rep.submit(turn(6, 3, SessionMode::Continue, vec![5, 1], 2));
    let err = collect(&rx).expect_err("quarantined session must be a definitive miss");
    assert!(err.to_string().contains("unknown session"), "{err}");

    // The replica keeps admitting fresh sessions throughout.
    let s2 = tasks::passkey(&mut rng, 400, 0.5);
    let rx = rep.submit(turn(7, 4, SessionMode::Open, s2.prompt.clone(), 2));
    let (tokens, _) = collect(&rx).expect("replica must survive the restore storm");
    assert!(s2.passed(&tokens));
    drop(rep);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wave_slot_faults_are_contained_and_survivors_bit_identical() {
    let eng = Engine::from_config(base_cfg()).expect("engine init");
    let ctrl = Engine::from_config(base_cfg()).expect("control engine init");
    let mut rng = Rng::seed_from(107);
    let ta = tasks::passkey(&mut rng, 500, 0.3);
    let tb = tasks::passkey(&mut rng, 500, 0.6);
    let tc = tasks::passkey(&mut rng, 500, 0.4);
    let mut sa = eng.prefill(&ta.prompt).unwrap();
    let mut sb = eng.prefill(&tb.prompt).unwrap();
    let mut sb_ctrl = ctrl.prefill(&tb.prompt).unwrap();

    // Error action: the injected slot fails cleanly; the survivor's token
    // is bit-identical to a solo decode of the same session.
    failpoint::reset();
    failpoint::arm("wave.decode", FailAction::Error { after: 0, times: 1 });
    let mut items =
        vec![WaveItem { sess: &mut sa, token: 5 }, WaveItem { sess: &mut sb, token: 5 }];
    let out = eng.decode_wave(&mut items);
    drop(items);
    assert_eq!(out.len(), 2);
    match &out[0] {
        Err(e) => assert!(format!("{e:#}").contains("wave.decode"), "{e:#}"),
        Ok(_) => panic!("injected slot must fail"),
    }
    let tok_b = match &out[1] {
        Ok(o) => o.token,
        Err(e) => panic!("survivor slot failed: {e:#}"),
    };
    let ctrl_tok = ctrl.decode_step(&mut sb_ctrl, 5).unwrap().token;
    assert_eq!(tok_b, ctrl_tok, "survivor diverged from solo decode under slot error");

    // Panic action: contained per slot (the wave must not unwind), same
    // survivor bit-identity — and the survivor keeps decoding in step
    // with the control afterwards.
    let mut sc = eng.prefill(&tc.prompt).unwrap();
    failpoint::reset();
    failpoint::arm("wave.decode", FailAction::Panic { after: 0 });
    let mut items =
        vec![WaveItem { sess: &mut sc, token: 5 }, WaveItem { sess: &mut sb, token: tok_b }];
    let out = eng.decode_wave(&mut items);
    drop(items);
    match &out[0] {
        Err(e) => assert!(format!("{e:#}").contains("panic"), "{e:#}"),
        Ok(_) => panic!("panicking slot must fail, not unwind the wave"),
    }
    let tok_b2 = match &out[1] {
        Ok(o) => o.token,
        Err(e) => panic!("survivor slot failed under sibling panic: {e:#}"),
    };
    let ctrl_tok2 = ctrl.decode_step(&mut sb_ctrl, ctrl_tok).unwrap().token;
    assert_eq!(tok_b2, ctrl_tok2, "survivor diverged from solo decode under slot panic");
    failpoint::reset();
    for s in [&mut sa, &mut sb, &mut sc, &mut sb_ctrl] {
        s.shutdown_maintenance();
    }
}

#[test]
fn maintenance_publish_faults_are_clean_retries() {
    failpoint::reset();
    let mut rng = Rng::seed_from(109);
    let keys = KeyStore::from_matrix(Matrix::from_fn(64, 8, |_, _| rng.normal()));
    let ids: Vec<u32> = (0..64).collect();
    let queries = Matrix::from_fn(16, 8, |_, _| rng.normal());
    let rcfg = RetrievalConfig::default();
    let inp = RetrieverInputs::from_parts(keys, ids, &queries, 0.35, &rcfg, 7);
    let group = inp.group.clone();
    let head: Arc<dyn HostRetriever> = Arc::from(build_retriever(Method::Flat, inp));
    let mut state = MaintenanceState::new();
    // Identical job per call: a failed (ok: false) publish mutated
    // nothing, so the engine's later-step retry resubmits the same batch.
    let mk_drain = |seed: u64, lo: u32, hi: u32| {
        let mut r = Rng::seed_from(seed);
        Job::Drain(DrainJob {
            layer: 0,
            kvh: 0,
            rows: Matrix::from_fn((hi - lo) as usize, 8, |_, _| r.normal()),
            ids: (lo..hi).collect(),
            upto: hi as usize,
            grow_store: true,
            heads: vec![head.clone()],
            queries: vec![None],
            group: group.clone(),
        })
    };

    // (a) Injected error before the drain publish: ok = false, nothing
    // mutated; the resubmitted job lands.
    failpoint::arm("maint.drain.publish", FailAction::Error { after: 0, times: 1 });
    state.submit(mk_drain(1, 64, 72));
    let dones = state.flush();
    assert_eq!(dones.len(), 1);
    assert!(!dones[0].ok, "injected publish fault must report a clean retry");
    assert_eq!(group.id_map().len(), 64, "failed publish must not mutate the group");
    assert_eq!(head.index_generation(), 0, "failed publish must not swap the front");
    state.submit(mk_drain(1, 64, 72));
    let dones = state.flush();
    assert!(dones[0].ok, "retried drain must land");
    assert_eq!(group.id_map().len(), 72);

    // (b) Panic inside the job: containment synthesizes the same ok=false
    // completion from job metadata, and the worker thread survives.
    failpoint::reset();
    failpoint::arm("maint.drain.publish", FailAction::Panic { after: 0 });
    state.submit(mk_drain(2, 72, 80));
    let dones = state.flush();
    assert_eq!(dones.len(), 1, "panicked job must still complete (synthesized)");
    assert!(!dones[0].ok);
    assert!(matches!(dones[0].kind, DoneKind::Drained { upto: 80, count: 8 }));
    assert_eq!(group.id_map().len(), 72, "panicked job must not mutate the group");
    state.submit(mk_drain(2, 72, 80));
    let dones = state.flush();
    assert!(dones[0].ok, "worker must survive a contained panic");
    assert_eq!(group.id_map().len(), 80);

    // (c) Compact publish fault: the epoch is skipped whole — generation
    // unchanged — and the retried epoch completes.
    state.submit(Job::Evict(EvictJob {
        layer: 0,
        kvh: 0,
        ids: (0..12).collect(),
        heads: vec![head.clone()],
        group: group.clone(),
    }));
    let _ = state.flush();
    failpoint::reset();
    failpoint::arm("maint.compact.publish", FailAction::Error { after: 0, times: 1 });
    let mk_compact = || {
        Job::Compact(CompactJob {
            layer: 0,
            kvh: 0,
            heads: vec![head.clone()],
            group: group.clone(),
        })
    };
    state.submit(mk_compact());
    let dones = state.flush();
    assert!(!dones[0].ok, "injected epoch fault must skip cleanly");
    assert_eq!(group.store_generation(), 0, "failed epoch must not bump the generation");
    state.submit(mk_compact());
    let dones = state.shutdown();
    assert!(dones[0].ok, "retried epoch must land");
    assert!(matches!(dones[0].kind, DoneKind::Compacted { dropped: 12 }));
    assert_eq!(group.store_generation(), 1);
    failpoint::reset();
}

#[test]
fn worker_panic_respawns_and_recovers_parked_sessions() {
    let dir = tmpdir("respawn");
    let ctrl_dir = tmpdir("respawn-ctrl");
    let rep = Replica::spawn(durable_cfg(&dir));
    let ctrl = Replica::spawn(durable_cfg(&ctrl_dir));
    let mut rng = Rng::seed_from(111);
    let s = tasks::passkey(&mut rng, 400, 0.3);
    for (r, tag) in [(&rep, "victim"), (&ctrl, "control")] {
        let rx = r.submit(turn(1, 7, SessionMode::Open, s.prompt.clone(), 2));
        let (tokens, _) = collect(&rx).unwrap_or_else(|e| panic!("{tag} open failed: {e}"));
        assert!(s.passed(&tokens), "{tag}: wrong first answer");
    }
    assert!(dir.join("session-7.ras").exists(), "open turn must have parked durably");

    // Kill the victim's worker thread between waves: the panic-only
    // `worker.step` site fires at the top of the next loop turn.
    failpoint::reset();
    failpoint::arm("worker.step", FailAction::Panic { after: 0 });
    let rx = rep.submit(Request { id: 2, prompt: s.prompt.clone(), max_tokens: 1, session: None });
    let _ = collect(&rx); // may complete or die with the worker — both are fine
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    while failpoint::hits("worker.step") == 0 {
        assert!(std::time::Instant::now() < deadline, "worker never hit the kill switch");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }

    // The next continue turn respawns the worker, whose boot scan
    // recovers session 7 from the durable tier. Turns racing the crash
    // may fail by disconnect (the documented crash semantics) — retry,
    // exactly as a client would.
    let cont = vec![9, 2, 6];
    let mut recovered = None;
    for attempt in 0..200u64 {
        let rx = rep.submit(turn(10 + attempt, 7, SessionMode::Continue, cont.clone(), 2));
        match collect(&rx) {
            Ok(out) => {
                recovered = Some(out);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    }
    let (tokens, m) = recovered.expect("continue never succeeded after the crash");
    assert_eq!(rep.respawn_count(), 1, "supervision must have respawned exactly once");
    assert!(m.resumed_from_disk, "recovery must come through the durable snapshot");

    // Token-identical continuation vs the never-crashed control replica.
    let rx = ctrl.submit(turn(3, 7, SessionMode::Continue, cont.clone(), 2));
    let (ctrl_tokens, cm) = collect(&rx).expect("control continue");
    assert!(cm.resumed_from_disk);
    assert_eq!(tokens, ctrl_tokens, "post-crash continuation diverged from control");

    failpoint::reset();
    drop(rep);
    drop(ctrl);
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&ctrl_dir);
}

/// Kill a replica's worker thread and drive the continue-retry loop until
/// the respawned worker serves a turn. Returns the recovered turn's
/// metrics. Shared by the flight-recorder and wave-telemetry tests below.
fn crash_and_recover(rep: &Replica, prompt: &[u32]) -> retrieval_attention::coordinator::RequestMetrics {
    failpoint::reset();
    failpoint::arm("worker.step", FailAction::Panic { after: 0 });
    let rx = rep.submit(Request { id: 2, prompt: prompt.to_vec(), max_tokens: 1, session: None });
    let _ = collect(&rx); // may complete or die with the worker — both are fine
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    while failpoint::hits("worker.step") == 0 {
        assert!(std::time::Instant::now() < deadline, "worker never hit the kill switch");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let mut recovered = None;
    for attempt in 0..200u64 {
        let rx = rep.submit(turn(10 + attempt, 7, SessionMode::Continue, vec![9, 2, 6], 2));
        match collect(&rx) {
            Ok(out) => {
                recovered = Some(out);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    }
    let (_, m) = recovered.expect("continue never succeeded after the crash");
    assert_eq!(rep.respawn_count(), 1, "supervision must have respawned exactly once");
    m
}

/// Acceptance: a forced worker crash leaves a parseable flight-recorder
/// dump in the spill dir whose tail explains the crash — the injected
/// failpoint event followed by the supervisor's respawn event.
#[test]
fn worker_crash_dumps_a_parseable_flight_recorder() {
    use retrieval_attention::util::json::{self, Value};
    let dir = tmpdir("flightrec");
    let rep = Replica::spawn(durable_cfg(&dir));
    let mut rng = Rng::seed_from(115);
    let s = tasks::passkey(&mut rng, 400, 0.3);
    let rx = rep.submit(turn(1, 7, SessionMode::Open, s.prompt.clone(), 2));
    collect(&rx).expect("open turn");
    let m = crash_and_recover(&rep, &s.prompt);
    assert!(m.resumed_from_disk);

    let dump: PathBuf = std::fs::read_dir(&dir)
        .expect("spill dir readable")
        .flatten()
        .map(|e| e.path())
        .find(|p| {
            p.file_name()
                .map(|n| {
                    let n = n.to_string_lossy();
                    n.starts_with("flightrec-") && n.ends_with(".jsonl")
                })
                .unwrap_or(false)
        })
        .expect("respawn must dump a flightrec-<ts>.jsonl into the spill dir");
    let body = std::fs::read_to_string(&dump).expect("dump readable");
    let mut kinds = Vec::new();
    let mut last_seq = 0u64;
    for (i, line) in body.lines().enumerate() {
        let v = json::parse(line).unwrap_or_else(|e| panic!("line {} unparseable: {e}", i + 1));
        let seq = v.get("seq").and_then(Value::as_u64).expect("seq field");
        assert!(i == 0 || seq > last_seq, "seq must be strictly increasing");
        last_seq = seq;
        assert!(v.get("ts_ms").and_then(Value::as_u64).is_some(), "ts_ms field");
        kinds.push((
            v.req_str("kind").expect("kind field").to_string(),
            v.req_str("detail").expect("detail field").to_string(),
        ));
    }
    // The tail explains the crash: the injected worker.step panic is the
    // last event before the supervisor's respawn record, which is last
    // (the dump happens at respawn time, after the event is pushed).
    let (last_kind, _) = kinds.last().expect("dump must not be empty");
    assert_eq!(last_kind, "respawn", "tail of the dump: {kinds:?}");
    assert!(
        kinds.iter().any(|(k, d)| k == "failpoint" && d.contains("worker.step")),
        "injected failpoint missing from the dump: {kinds:?}"
    );
    failpoint::reset();
    drop(rep);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Regression (wave-telemetry underflow): admission snapshots must never
/// straddle a respawn. The respawned worker starts a fresh WaveTelemetry
/// AND a fresh resident set, so a post-crash turn's deltas are computed
/// against counters that were both born in the same worker generation —
/// occupancy and throughput stay finite and sane instead of wrapping.
#[test]
fn post_respawn_wave_telemetry_never_underflows() {
    let dir = tmpdir("tele-respawn");
    let rep = Replica::spawn(durable_cfg(&dir));
    let mut rng = Rng::seed_from(117);
    let s = tasks::passkey(&mut rng, 400, 0.3);
    let rx = rep.submit(turn(1, 7, SessionMode::Open, s.prompt.clone(), 2));
    let (_, m0) = collect(&rx).expect("open turn");
    assert_eq!(m0.sessions_recovered, 0, "fresh boot has nothing to recover");
    let m = crash_and_recover(&rep, &s.prompt);
    assert!(m.resumed_from_disk);
    // The recovery counters surface end-to-end (PR 9 provenance).
    assert!(m.sessions_recovered >= 1, "boot scan must report the recovered session");
    assert_eq!(m.snapshots_quarantined, 0);
    // Saturating-delta sanity: a wrapped subtraction would blow any of
    // these past physical plausibility.
    assert!(
        m.wave_occupancy_mean.is_finite() && m.wave_occupancy_mean >= 0.0,
        "occupancy underflowed: {}",
        m.wave_occupancy_mean
    );
    assert!(
        m.wave_occupancy_mean <= 1024.0,
        "occupancy mean {} exceeds any plausible wave size",
        m.wave_occupancy_mean
    );
    assert!(
        m.replica_tokens_per_s.is_finite() && m.replica_tokens_per_s >= 0.0,
        "throughput underflowed: {}",
        m.replica_tokens_per_s
    );
    assert!(m.max_gap_waves < 1_000_000, "gap counter wrapped: {}", m.max_gap_waves);
    failpoint::reset();
    drop(rep);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn respawn_budget_exhaustion_fails_explicitly() {
    let mut cfg = base_cfg();
    cfg.serving.max_respawns = 0;
    let rep = Replica::spawn(cfg);
    let mut rng = Rng::seed_from(113);
    let s = tasks::passkey(&mut rng, 400, 0.5);
    let rx = rep.submit(Request { id: 1, prompt: s.prompt.clone(), max_tokens: 1, session: None });
    collect(&rx).expect("replica healthy before the kill");

    failpoint::reset();
    failpoint::arm("worker.step", FailAction::Panic { after: 0 });
    let rx = rep.submit(Request { id: 2, prompt: s.prompt.clone(), max_tokens: 1, session: None });
    let _ = collect(&rx);
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    while failpoint::hits("worker.step") == 0 {
        assert!(std::time::Instant::now() < deadline, "worker never hit the kill switch");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }

    // With no respawn budget, every further submit must surface the
    // explicit terminal failure (a disconnect is the only acceptable
    // interim shape while the dead thread is still being reaped).
    let mut msg = String::new();
    for i in 0..200u64 {
        let rx =
            rep.submit(Request { id: 10 + i, prompt: vec![1, 2, 3], max_tokens: 1, session: None });
        msg = collect(&rx)
            .expect_err("dead replica with no respawn budget must fail")
            .to_string();
        if msg.contains("replica worker is gone") {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(msg.contains("replica worker is gone"), "unexpected terminal error: {msg}");
    assert_eq!(rep.respawn_count(), 0, "exhausted budget must never respawn");
    failpoint::reset();
}
