//! Cross-layer consistency: the device-side entry points (compiled Pallas
//! artifacts through PJRT when available, the runtime's native backend
//! otherwise) must agree numerically with the host-side Rust
//! implementations (L3) — the exactness of the γ-combine depends on both
//! sides computing the same partial-softmax contract.

use retrieval_attention::attention::{attend_subset, combine, PartialAttention};
use retrieval_attention::runtime::{literal_to_f32, Runtime};
use retrieval_attention::tensor::Matrix;
use retrieval_attention::util::rng::Rng;

fn runtime(preset: &str) -> Runtime {
    // PJRT when `make artifacts` has run, native backend otherwise — the
    // consistency contract must hold for whichever device actually serves.
    Runtime::load_auto("artifacts", preset).expect("runtime")
}

/// Run the `static_attn` artifact on random data and compare (o, lse)
/// against the host implementation over the same tokens.
#[test]
fn device_static_attn_matches_host_attention() {
    let rt = runtime("llama3-mini");
    let spec = rt.meta().spec.clone();
    let (s, kv, h, dh) = (spec.static_len, spec.kv_heads, spec.q_heads, spec.head_dim);
    let group = spec.group_size();
    let mut rng = Rng::seed_from(42);

    let q: Vec<f32> = (0..h * dh).map(|_| rng.normal()).collect();
    let keys: Vec<f32> = (0..s * kv * dh).map(|_| rng.normal()).collect();
    let values: Vec<f32> = (0..s * kv * dh).map(|_| rng.normal()).collect();
    // Mask out a tail (simulates a short sequence).
    let valid = s - 100;
    let mask: Vec<f32> = (0..s).map(|i| if i < valid { 0.0 } else { -1.0e30 }).collect();

    let q_b = rt.upload_f32(&q, &[h, dh]).unwrap();
    let k_b = rt.upload_f32(&keys, &[s, kv, dh]).unwrap();
    let v_b = rt.upload_f32(&values, &[s, kv, dh]).unwrap();
    let m_b = rt.upload_f32(&mask, &[s]).unwrap();
    let outs = rt.exec_b("static_attn", &[&q_b, &k_b, &v_b, &m_b]).unwrap();
    let o_dev = literal_to_f32(&outs[0]).unwrap();
    let lse_dev = literal_to_f32(&outs[1]).unwrap();

    // Host reference: same computation per query head.
    let scale = 1.0 / (dh as f32).sqrt();
    for head in 0..h {
        let kvh = head / group;
        // Gather this head's K/V into matrices over the valid tokens.
        let mut k_m = Matrix::zeros(0, dh);
        let mut v_m = Matrix::zeros(0, dh);
        for t in 0..valid {
            let off = (t * kv + kvh) * dh;
            k_m.push_row(&keys[off..off + dh]);
            v_m.push_row(&values[off..off + dh]);
        }
        let ids: Vec<u32> = (0..valid as u32).collect();
        let part = attend_subset(&q[head * dh..(head + 1) * dh], &k_m, &v_m, &ids, scale);
        for (a, b) in part.o.iter().zip(&o_dev[head * dh..(head + 1) * dh]) {
            assert!((a - b).abs() < 1e-3, "head {head}: o mismatch {a} vs {b}");
        }
        assert!(
            (part.lse - lse_dev[head]).abs() < 1e-3,
            "head {head}: lse mismatch {} vs {}",
            part.lse,
            lse_dev[head]
        );
    }
}

/// Device combine kernel vs host combine on the same partials.
#[test]
fn device_combine_matches_host_combine() {
    let rt = runtime("llama3-mini");
    let spec = rt.meta().spec.clone();
    let (h, dh) = (spec.q_heads, spec.head_dim);
    let mut rng = Rng::seed_from(7);
    let o1: Vec<f32> = (0..h * dh).map(|_| rng.normal()).collect();
    let o2: Vec<f32> = (0..h * dh).map(|_| rng.normal()).collect();
    let l1: Vec<f32> = (0..h).map(|_| rng.normal() * 3.0).collect();
    let l2: Vec<f32> = (0..h).map(|_| rng.normal() * 3.0).collect();

    let b1 = rt.upload_f32(&o1, &[h, dh]).unwrap();
    let b2 = rt.upload_f32(&l1, &[h]).unwrap();
    let b3 = rt.upload_f32(&o2, &[h, dh]).unwrap();
    let b4 = rt.upload_f32(&l2, &[h]).unwrap();
    let outs = rt.exec_b("combine", &[&b1, &b2, &b3, &b4]).unwrap();
    let o_dev = literal_to_f32(&outs[0]).unwrap();
    let lse_dev = literal_to_f32(&outs[1]).unwrap();

    for head in 0..h {
        let p1 = PartialAttention {
            o: o1[head * dh..(head + 1) * dh].to_vec(),
            lse: l1[head],
        };
        let p2 = PartialAttention {
            o: o2[head * dh..(head + 1) * dh].to_vec(),
            lse: l2[head],
        };
        let merged = combine(&[p1, p2]);
        for (a, b) in merged.o.iter().zip(&o_dev[head * dh..(head + 1) * dh]) {
            assert!((a - b).abs() < 1e-4, "head {head}: combine o mismatch {a} vs {b}");
        }
        assert!((merged.lse - lse_dev[head]).abs() < 1e-4, "head {head}: combine lse mismatch");
    }
}

/// The end-to-end γ contract through real artifacts: device W-partial +
/// host Ω-partial combined equals host attention over W ∪ Ω.
#[test]
fn gamma_combine_exact_across_layers() {
    let rt = runtime("yi6-mini");
    let spec = rt.meta().spec.clone();
    let (s, kv, h, dh) = (spec.static_len, spec.kv_heads, spec.q_heads, spec.head_dim);
    assert_eq!(kv, 1, "test assumes single kv head for brevity");
    let scale = 1.0 / (dh as f32).sqrt();
    let mut rng = Rng::seed_from(11);

    // A corpus of s + extra tokens: first s on the "device", rest on host.
    let extra = 300;
    let total = s + extra;
    let all_k = Matrix::from_fn(total, dh, |_, _| rng.normal());
    let all_v = Matrix::from_fn(total, dh, |_, _| rng.normal());
    let q: Vec<f32> = (0..h * dh).map(|_| rng.normal()).collect();

    // Device partial over tokens [0, s).
    let keys: Vec<f32> = (0..s).flat_map(|t| all_k.row(t).to_vec()).collect();
    let values: Vec<f32> = (0..s).flat_map(|t| all_v.row(t).to_vec()).collect();
    let mask = vec![0.0f32; s];
    let q_b = rt.upload_f32(&q, &[h, dh]).unwrap();
    // Pre-scale is applied inside the artifact; keys shaped [s, kv=1, dh].
    let k_b = rt.upload_f32(&keys, &[s, 1, dh]).unwrap();
    let v_b = rt.upload_f32(&values, &[s, 1, dh]).unwrap();
    let m_b = rt.upload_f32(&mask, &[s]).unwrap();
    let outs = rt.exec_b("static_attn", &[&q_b, &k_b, &v_b, &m_b]).unwrap();
    let o_dev = literal_to_f32(&outs[0]).unwrap();
    let lse_dev = literal_to_f32(&outs[1]).unwrap();

    for head in 0..h {
        let qh = &q[head * dh..(head + 1) * dh];
        let dev = PartialAttention {
            o: o_dev[head * dh..(head + 1) * dh].to_vec(),
            lse: lse_dev[head],
        };
        // Host partial over the remaining tokens.
        let host_ids: Vec<u32> = (s as u32..total as u32).collect();
        let host = attend_subset(qh, &all_k, &all_v, &host_ids, scale);
        let merged = combine(&[dev, host]);
        // Ground truth: host attention over everything.
        let all_ids: Vec<u32> = (0..total as u32).collect();
        let truth = attend_subset(qh, &all_k, &all_v, &all_ids, scale);
        for (a, b) in merged.o.iter().zip(truth.o.iter()) {
            assert!((a - b).abs() < 1e-3, "head {head}: e2e gamma mismatch {a} vs {b}");
        }
    }
}
