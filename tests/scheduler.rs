//! Continuous-batching scheduler suite: the wave loop's headline
//! invariant — batched decode is **bit-identical** to serial decode —
//! plus the fairness bound and session-verb liveness under load.
//!
//! The equivalence tests run every index family × every quant mode with
//! inline (synchronous) maintenance: the async worker's completion timing
//! is scheduler-dependent, so bit-identity is only a meaningful claim
//! when drains land at deterministic token positions. The wave fusion
//! itself must then be invisible: `par_map` is order-preserving and the
//! fused kernels (`dot_gather_mq`, `attend_group_mq`) are property-locked
//! bitwise against their per-head forms.

use retrieval_attention::config::{Method, ServeConfig};
use retrieval_attention::coordinator::{collect, Replica, Request, SessionMode, SessionSpec};
use retrieval_attention::kernel::QuantMode;
use retrieval_attention::kvcache::StaticPattern;
use retrieval_attention::model::Engine;
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::tasks;

fn wave_cfg(method: Method, quant: QuantMode) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = method;
    cfg.pattern = StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.quant.mode = quant;
    // Bit-identity requires deterministic maintenance placement: inline
    // drains happen at the same token index no matter how sessions are
    // interleaved across waves. A low watermark makes drains actually
    // fire inside the decode window under test.
    cfg.retrieval.maintenance.async_worker = false;
    cfg.retrieval.maintenance.drain_watermark = 2;
    cfg
}

/// Serial reference: each prompt decoded alone on a fresh engine built
/// from the same config (same seed ⇒ same weights as the replica's).
fn serial_tokens(cfg: &ServeConfig, prompts: &[Vec<u32>], max_tokens: usize) -> Vec<Vec<u32>> {
    let eng = Engine::from_config(cfg.clone()).expect("engine init");
    prompts
        .iter()
        .map(|p| {
            let mut sess = eng.prefill(p).expect("prefill");
            let (tokens, _) = eng.generate(&mut sess, max_tokens).expect("generate");
            sess.shutdown_maintenance();
            tokens
        })
        .collect()
}

/// Batched: all prompts submitted to one replica, decoding together in
/// fused waves. `stagger` delays each submit so later sessions join
/// mid-stream while earlier ones are already decoding.
fn batched_tokens(
    cfg: &ServeConfig,
    prompts: &[Vec<u32>],
    max_tokens: usize,
    stagger: Option<std::time::Duration>,
) -> Vec<Vec<u32>> {
    let replica = Replica::spawn(cfg.clone());
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            if i > 0 {
                if let Some(d) = stagger {
                    std::thread::sleep(d);
                }
            }
            replica.submit(Request { id: i as u64, prompt: p.clone(), max_tokens, session: None })
        })
        .collect();
    let out: Vec<Vec<u32>> =
        rxs.iter().map(|rx| collect(rx).expect("batched request failed").0).collect();
    assert_eq!(replica.outstanding(), 0, "all requests retired");
    out
}

fn passkey_prompts(seed: u64, n: usize, len: usize) -> Vec<Vec<u32>> {
    let mut rng = Rng::seed_from(seed);
    (0..n)
        .map(|i| tasks::passkey(&mut rng, len, 0.15 + 0.3 * i as f64 / n.max(1) as f64).prompt)
        .collect()
}

/// The tentpole invariant, across every index family and quant mode:
/// a wave of sessions produces exactly the tokens each session would
/// produce decoding alone.
#[test]
fn batched_decode_is_bit_identical_to_serial() {
    let families = [Method::Flat, Method::Ivf, Method::Hnsw, Method::RetrievalAttention];
    let quants = [QuantMode::Off, QuantMode::Fp16, QuantMode::Int8];
    for family in families {
        for quant in quants {
            let cfg = wave_cfg(family, quant);
            let prompts = passkey_prompts(42, 2, 288);
            let serial = serial_tokens(&cfg, &prompts, 3);
            let batched = batched_tokens(&cfg, &prompts, 3, None);
            assert_eq!(
                serial, batched,
                "wave decode diverged from serial for {family:?}/{quant:?}"
            );
        }
    }
}

/// Mid-stream joins: sessions admitted while earlier ones are already
/// waves deep must neither perturb them nor decode differently
/// themselves.
#[test]
fn mid_stream_joins_preserve_bit_identity() {
    let cfg = wave_cfg(Method::RetrievalAttention, QuantMode::Off);
    let prompts = passkey_prompts(43, 3, 288);
    let serial = serial_tokens(&cfg, &prompts, 8);
    let batched = batched_tokens(&cfg, &prompts, 8, Some(std::time::Duration::from_millis(30)));
    assert_eq!(serial, batched, "mid-stream join changed decoded tokens");
}

/// The fairness bound: under saturation (4 residents, wave_size 1) no
/// session's inter-token gap may exceed `fairness_waves` waves.
#[test]
fn throttled_waves_respect_the_fairness_bound() {
    let mut cfg = wave_cfg(Method::Flat, QuantMode::Off);
    cfg.scheduler.wave_size = 1;
    cfg.scheduler.fairness_waves = 3;
    cfg.scheduler.max_batch = 4;
    let prompts = passkey_prompts(44, 4, 288);
    let replica = Replica::spawn(cfg);
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            replica.submit(Request {
                id: i as u64,
                prompt: p.clone(),
                max_tokens: 6,
                session: None,
            })
        })
        .collect();
    for (i, rx) in rxs.iter().enumerate() {
        let (tokens, m) = collect(rx).expect("request failed under saturation");
        assert_eq!(tokens.len(), 6);
        assert!(m.max_gap_waves >= 1, "request {i}: gap accounting never ran");
        assert!(
            m.max_gap_waves <= 3,
            "request {i}: inter-token gap {} waves exceeds fairness bound 3",
            m.max_gap_waves
        );
        assert!(m.wave_occupancy_mean > 0.0, "request {i}: occupancy not recorded");
        assert!(m.replica_tokens_per_s > 0.0, "request {i}: throughput not recorded");
    }
    assert_eq!(replica.outstanding(), 0);
}

/// The head-policy layer's do-no-harm invariant, across every index
/// family and quant mode: a calibrated policy whose decision is forced
/// back to all-Retrieval (mass threshold met everywhere, but every head
/// pinned by `force_retrieval`) decodes bit-identically to policy-off.
/// Calibration rides the LSEs the combine step already computes, so a
/// no-flip decision must be invisible to the token stream.
#[test]
fn forced_all_retrieval_policy_is_bit_identical_to_policy_off() {
    use retrieval_attention::policy::PolicyMode;
    let families = [Method::Flat, Method::Ivf, Method::Hnsw, Method::RetrievalAttention];
    let quants = [QuantMode::Off, QuantMode::Fp16, QuantMode::Int8];
    for family in families {
        for quant in quants {
            let off = wave_cfg(family, quant);
            let mut forced = wave_cfg(family, quant);
            forced.policy.mode = PolicyMode::Calibrated;
            forced.policy.calibration_steps = 2;
            // Threshold 0 makes every head WANT to flip; the retrieval
            // pins must win, leaving the decode untouched.
            forced.policy.mass_threshold = 0.0;
            forced.policy.force_retrieval = vec![(0, 0), (1, 0)];
            let prompts = passkey_prompts(46, 2, 288);
            // 4 tokens: the decision lands after step 2, mid-stream.
            let baseline = serial_tokens(&off, &prompts, 4);
            assert_eq!(
                baseline,
                serial_tokens(&forced, &prompts, 4),
                "forced-all-retrieval serial decode diverged for {family:?}/{quant:?}"
            );
            assert_eq!(
                baseline,
                batched_tokens(&forced, &prompts, 4, None),
                "forced-all-retrieval wave decode diverged for {family:?}/{quant:?}"
            );
        }
    }
}

/// Mixed-policy sessions (streaming layer 1, retrieval layer 0 on the
/// 2-layer induction model) must keep the batched-vs-serial invariant:
/// heterogeneous retriever stacks fuse into waves without perturbing
/// either tier. Also checks the policy metrics surface in done events.
#[test]
fn mixed_policy_sessions_keep_batched_serial_identity() {
    use retrieval_attention::policy::PolicyMode;
    let mut cfg = wave_cfg(Method::RetrievalAttention, QuantMode::Off);
    cfg.policy.mode = PolicyMode::Static;
    cfg.policy.force_streaming = vec![(1, 0)];
    // Small span so the streaming head actually truncates the drained
    // overflow (≈128 ids by end of decode) instead of returning it all.
    cfg.policy.sinks = 8;
    cfg.policy.window = 32;
    let prompts = passkey_prompts(47, 3, 288);
    let serial = serial_tokens(&cfg, &prompts, 8);
    let replica = Replica::spawn(cfg.clone());
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            replica.submit(Request { id: i as u64, prompt: p.clone(), max_tokens: 8, session: None })
        })
        .collect();
    for (i, rx) in rxs.iter().enumerate() {
        let (tokens, m) = collect(rx).expect("mixed-policy request failed");
        assert_eq!(tokens, serial[i], "mixed-policy wave diverged from serial for prompt {i}");
        assert_eq!(
            m.streaming_head_fraction, 0.5,
            "request {i}: expected 1 of 2 heads streaming"
        );
    }
    assert_eq!(replica.outstanding(), 0);
}

/// The telemetry leg of the equivalence suite: decoding with structured
/// tracing enabled (`serving.telemetry.spans = true`) is bit-identical to
/// decoding with it off, serial and batched, across every index family.
/// Spans only read clocks and bump accumulators — they must never touch
/// the compute. (The spans flag is process-global, so other tests in this
/// binary may observe it flipping; that is safe for the same reason this
/// test passes: timing state cannot influence tokens.)
#[test]
fn tracing_on_decode_is_bit_identical_to_tracing_off() {
    let families = [Method::Flat, Method::Ivf, Method::Hnsw, Method::RetrievalAttention];
    for family in families {
        let off = wave_cfg(family, QuantMode::Off);
        let mut on = wave_cfg(family, QuantMode::Off);
        on.serving.telemetry.spans = true;
        let prompts = passkey_prompts(48, 2, 288);
        let baseline = serial_tokens(&off, &prompts, 4);
        assert_eq!(
            baseline,
            serial_tokens(&on, &prompts, 4),
            "tracing-on serial decode diverged for {family:?}"
        );
        assert_eq!(
            baseline,
            batched_tokens(&on, &prompts, 4, None),
            "tracing-on wave decode diverged for {family:?}"
        );
    }
    // With spans on, the done event carries a populated span tree.
    let mut cfg = wave_cfg(Method::RetrievalAttention, QuantMode::Off);
    cfg.serving.telemetry.spans = true;
    let prompts = passkey_prompts(48, 1, 288);
    let replica = Replica::spawn(cfg);
    let rx =
        replica.submit(Request { id: 1, prompt: prompts[0].clone(), max_tokens: 4, session: None });
    let (_, m) = collect(&rx).expect("traced request failed");
    assert!(!m.spans.is_empty(), "spans flag on but the request's span tree is empty");
    assert!(m.spans.total_s() > 0.0, "span tree carries no wall time");
}

/// Session verbs landing mid-stream (continue on a retained session,
/// close on an unknown one) are registry operations: they must complete
/// and must never stall a session that is already decoding.
#[test]
fn session_verbs_never_stall_other_sessions() {
    let cfg = wave_cfg(Method::RetrievalAttention, QuantMode::Off);
    let replica = Replica::spawn(cfg);
    let mut rng = Rng::seed_from(45);
    // Turn 1: open retains session 7.
    let s1 = tasks::passkey(&mut rng, 288, 0.4);
    let rx = replica.submit(Request {
        id: 1,
        prompt: s1.prompt.clone(),
        max_tokens: 2,
        session: Some(SessionSpec { session_id: 7, mode: SessionMode::Open }),
    });
    let (t1, _) = collect(&rx).expect("open turn failed");
    assert!(s1.passed(&t1), "open turn wrong: {t1:?}");
    // A long-running plain request occupies the wave loop...
    let s2 = tasks::passkey(&mut rng, 288, 0.7);
    let rx_long = replica.submit(Request {
        id: 2,
        prompt: s2.prompt.clone(),
        max_tokens: 10,
        session: None,
    });
    // ...while a continue turn and a close-of-unknown land mid-stream.
    let rx_cont = replica.submit(Request {
        id: 3,
        prompt: vec![5, 1],
        max_tokens: 2,
        session: Some(SessionSpec { session_id: 7, mode: SessionMode::Continue }),
    });
    let rx_bogus = replica.submit(Request {
        id: 4,
        prompt: Vec::new(),
        max_tokens: 0,
        session: Some(SessionSpec { session_id: 99, mode: SessionMode::Close }),
    });
    let (t_long, _) = collect(&rx_long).expect("long request stalled");
    assert_eq!(t_long.len(), 10, "long request lost tokens to a session verb");
    let (t_cont, _) = collect(&rx_cont).expect("continue turn failed");
    assert_eq!(t_cont.len(), 2);
    assert!(collect(&rx_bogus).is_err(), "closing an unknown session must fail");
    // Clean close of the real session; everything retired exactly once.
    let rx_close = replica.submit(Request {
        id: 5,
        prompt: Vec::new(),
        max_tokens: 0,
        session: Some(SessionSpec { session_id: 7, mode: SessionMode::Close }),
    });
    assert!(collect(&rx_close).is_ok(), "close of a retained session failed");
    assert_eq!(replica.outstanding(), 0);
    assert_eq!(replica.queue_depth(), 0);
}
