//! Persistence-subsystem tests: the session snapshot format and the
//! multi-turn session registry.
//!
//! The load-bearing claims, each pinned here:
//!
//! 1. **Bit-identical search**: a head serialized and restored returns
//!    exactly the ids (and scan counts) of the live head, for all four
//!    index families, with the quantized scan tier off and on, and across
//!    a reclamation-generation bump.
//! 2. **No re-prefill, no index rebuild**: an engine-level snapshot
//!    round-trips a decodable session whose maintenance stats start at
//!    zero (nothing was rebuilt) and whose subsequent tokens are
//!    identical to the never-snapshotted session's.
//! 3. **Disk transparency**: a multi-turn conversation forced through
//!    disk on every turn (`max_resident_bytes = 0`) produces
//!    token-identical output to the always-resident run, and exhausting
//!    `max_disk_bytes` rejects with backpressure instead of losing state.

use retrieval_attention::baselines::{
    build_retriever, restore_retriever, GroupShared, HostRetriever, RetrieverInputs,
};
use retrieval_attention::config::{Method, QuantConfig, RetrievalConfig, ServeConfig};
use retrieval_attention::coordinator::{collect, Replica, Request, SessionMode, SessionSpec};
use retrieval_attention::index::{KeyStore, RemapPlan};
use retrieval_attention::kernel::QuantMode;
use retrieval_attention::kvcache::StaticPattern;
use retrieval_attention::model::Engine;
use retrieval_attention::store::codec::{SnapReader, SnapWriter};
use retrieval_attention::tensor::Matrix;
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::tasks;
use std::sync::Arc;

const INDEX_METHODS: [Method; 4] =
    [Method::Flat, Method::Ivf, Method::Hnsw, Method::RetrievalAttention];

fn head_setup(
    quant: QuantMode,
    seed: u64,
) -> (KeyStore, Vec<u32>, Matrix, RetrievalConfig) {
    let mut rng = Rng::seed_from(seed);
    let d = 16usize;
    let n = 512usize;
    let keys = KeyStore::from_matrix(Matrix::from_fn(n, d, |_, _| rng.normal()));
    let ids: Vec<u32> = (0..n as u32).map(|i| i + 100).collect();
    let queries =
        Matrix::from_fn(64, d, |_, c| rng.normal() + if c < d / 4 { 1.0 } else { 0.0 });
    let mut cfg = RetrievalConfig::default();
    cfg.ef = 64;
    cfg.quant = QuantConfig { mode: quant, rerank: 2 };
    (keys, ids, queries, cfg)
}

fn save_head(head: &dyn HostRetriever) -> Vec<u8> {
    let mut buf: Vec<u8> = Vec::new();
    let mut w = SnapWriter::new(&mut buf);
    head.save_state(&mut w).expect("head must serialize");
    buf
}

fn restore_head(buf: &[u8], group: Arc<GroupShared>) -> Box<dyn HostRetriever> {
    let mut src = buf;
    let mut r = SnapReader::new(&mut src);
    restore_retriever(&mut r, group).expect("head must restore")
}

fn assert_bit_identical(
    a: &dyn HostRetriever,
    b: &dyn HostRetriever,
    queries: &Matrix,
    k: usize,
    tag: &str,
) {
    for qi in 0..queries.rows() {
        let q = queries.row(qi);
        let ra = a.retrieve(q, k);
        let rb = b.retrieve(q, k);
        assert_eq!(ra.ids, rb.ids, "{tag}: query {qi} ids diverged");
        assert_eq!(ra.scanned, rb.scanned, "{tag}: query {qi} scan count diverged");
    }
}

#[test]
fn head_snapshot_roundtrip_bit_identical_all_families_and_quant() {
    for (mi, method) in INDEX_METHODS.into_iter().enumerate() {
        for (qi, quant) in [QuantMode::Off, QuantMode::Fp16, QuantMode::Int8]
            .into_iter()
            .enumerate()
        {
            let (keys, ids, queries, cfg) =
                head_setup(quant, 1000 + (mi * 3 + qi) as u64);
            let inp =
                RetrieverInputs::from_parts(keys, ids.clone(), &queries, 0.25, &cfg, 7);
            let group = inp.group.clone();
            let head = build_retriever(method, inp);
            // Tombstone a band so the snapshot carries real deletion state.
            assert!(head.remove_batch(&ids[40..96]));
            let buf = save_head(head.as_ref());
            // The group round-trips through the same format.
            let mut gbuf: Vec<u8> = Vec::new();
            {
                let mut w = SnapWriter::new(&mut gbuf);
                retrieval_attention::store::save_group(&mut w, &group).unwrap();
            }
            let mut gsrc = gbuf.as_slice();
            let mut gr = SnapReader::new(&mut gsrc);
            let restored_group = retrieval_attention::store::load_group(&mut gr).unwrap();
            let restored = restore_head(&buf, restored_group);
            let tag = format!("{}/{:?}", method.label(), quant);
            assert_eq!(restored.name(), head.name(), "{tag}: label diverged");
            assert_eq!(restored.tombstones(), head.tombstones(), "{tag}");
            assert_eq!(restored.indexed_len(), head.indexed_len(), "{tag}");
            assert_bit_identical(head.as_ref(), restored.as_ref(), &queries, 20, &tag);
        }
    }
}

#[test]
fn head_snapshot_across_reclamation_generation_bump() {
    // Snapshot taken AFTER a reclamation epoch: dense ids were renumbered
    // under a bumped store generation; the snapshot must carry the
    // compacted store, the generation-stamped map, and fronts whose
    // searches stay bit-identical after restore.
    for (mi, method) in INDEX_METHODS.into_iter().enumerate() {
        let (keys, ids, queries, cfg) = head_setup(QuantMode::Int8, 2000 + mi as u64);
        let inp = RetrieverInputs::from_parts(keys, ids.clone(), &queries, 0.25, &cfg, 11);
        let group = inp.group.clone();
        let head = build_retriever(method, inp);
        assert!(head.remove_batch(&ids[..128]));
        assert!(head.supports_reclaim(), "{}: no reclaim support", method.label());
        // The production epoch flow: plan from the head's dead set,
        // publish map -> store, remap the front, release the old map.
        let dead = head.dense_dead_ids();
        let old_map = group.id_map();
        let gen = old_map.store_gen + 1;
        let (plan, keep) =
            RemapPlan::from_dead(&dead, &group.keys(), gen).expect("plan must build");
        let new_ids: Vec<u32> = keep.iter().map(|&o| old_map.ids[o as usize]).collect();
        let new_store = plan.store.clone();
        let plan = Arc::new(plan);
        group.publish_remap(new_ids, new_store, gen);
        assert!(head.apply_remap(&plan), "{}: remap refused", method.label());
        group.finish_remap();
        assert_eq!(group.store_generation(), gen);
        assert_eq!(head.tombstones(), 0);

        let buf = save_head(head.as_ref());
        let mut gbuf: Vec<u8> = Vec::new();
        {
            let mut w = SnapWriter::new(&mut gbuf);
            retrieval_attention::store::save_group(&mut w, &group).unwrap();
        }
        let mut gsrc = gbuf.as_slice();
        let mut gr = SnapReader::new(&mut gsrc);
        let restored_group = retrieval_attention::store::load_group(&mut gr).unwrap();
        assert_eq!(restored_group.store_generation(), gen, "generation lost in snapshot");
        let restored = restore_head(&buf, restored_group.clone());
        let tag = format!("{}/post-reclaim", method.label());
        assert_bit_identical(head.as_ref(), restored.as_ref(), &queries, 20, &tag);
        // The restored head keeps working online: a drain-style insert
        // against the restored group lands and retrieves.
        let grown = restored_group.extend(
            Matrix::from_fn(1, 16, |_, c| if c == 0 { 9.0 } else { 0.0 }),
            &[5000],
            true,
        );
        assert!(restored.insert_batch(
            &grown,
            &[5000],
            &retrieval_attention::index::InsertContext::none()
        ));
        let mut probe = vec![0.0f32; 16];
        probe[0] = 1.0;
        let out = restored.retrieve(&probe, 4);
        assert!(out.ids.contains(&5000), "{tag}: post-restore insert lost: {:?}", out.ids);
    }
}

#[test]
fn cow_fork_shares_frozen_state_and_diverges_on_write() {
    let (keys, ids, queries, cfg) = head_setup(QuantMode::Off, 3000);
    let inp = RetrieverInputs::from_parts(keys, ids.clone(), &queries, 0.25, &cfg, 13);
    let group = inp.group.clone();
    let head = build_retriever(Method::RetrievalAttention, inp);
    let forked_group = group.fork();
    assert_eq!(forked_group.store_generation(), group.store_generation());
    let fork = head.fork_with_group(forked_group.clone()).expect("index heads fork");
    assert_bit_identical(head.as_ref(), fork.as_ref(), &queries, 20, "fork");
    // A write on the BASE (drain-style insert) must not leak into the fork.
    let grown = group.extend(
        Matrix::from_fn(1, 16, |_, c| if c == 1 { 9.0 } else { 0.0 }),
        &[7000],
        true,
    );
    assert!(head.insert_batch(&grown, &[7000], &retrieval_attention::index::InsertContext::none()));
    let mut probe = vec![0.0f32; 16];
    probe[1] = 1.0;
    assert!(head.retrieve(&probe, 4).ids.contains(&7000), "base lost its own insert");
    assert!(
        !fork.retrieve(&probe, 64).ids.contains(&7000),
        "base write leaked into the fork"
    );
    // And the fork keeps its own write path.
    let fgrown = forked_group.extend(
        Matrix::from_fn(1, 16, |_, c| if c == 2 { 9.0 } else { 0.0 }),
        &[8000],
        true,
    );
    let ctx = retrieval_attention::index::InsertContext::none();
    assert!(fork.insert_batch(&fgrown, &[8000], &ctx));
    let mut probe2 = vec![0.0f32; 16];
    probe2[2] = 1.0;
    assert!(fork.retrieve(&probe2, 4).ids.contains(&8000), "fork lost its own insert");
    assert!(
        !head.retrieve(&probe2, 64).ids.contains(&8000),
        "fork write leaked into the base"
    );
}

fn engine_cfg(method: Method) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = method;
    cfg.pattern = StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.ef = 64;
    // Deterministic token streams: maintenance inline, and a watermark
    // high enough that the short decodes below never drain — the restored
    // session must show ZERO maintenance work (no rebuild, no insert).
    cfg.retrieval.maintenance.async_worker = false;
    cfg.retrieval.maintenance.drain_watermark = 1024;
    cfg
}

#[test]
fn engine_snapshot_roundtrip_decodes_identically() {
    // All four index families + the two trivially-persistable policies +
    // one rebuild-on-restore baseline (SnapKV: heads can't serialize, but
    // the snapshot's caches/queries rebuild them deterministically).
    for method in [
        Method::RetrievalAttention,
        Method::Flat,
        Method::Ivf,
        Method::Hnsw,
        Method::Full,
        Method::StreamingLlm,
        Method::SnapKv,
    ] {
        let eng = Engine::from_config(engine_cfg(method)).expect("engine init");
        let mut rng = Rng::seed_from(31);
        let s = tasks::passkey(&mut rng, 700, 0.3);
        let mut sess = eng.prefill(&s.prompt).unwrap();
        let (_, _) = eng.generate(&mut sess, 2).unwrap();

        let mut buf: Vec<u8> = Vec::new();
        let bytes = eng.snapshot_session(&mut sess, &mut buf).unwrap();
        assert_eq!(bytes, buf.len() as u64, "byte accounting diverged");
        assert!(bytes > 0);
        let mut src = buf.as_slice();
        let mut restored = eng.restore_session(&mut src).unwrap();

        assert_eq!(restored.len, sess.len, "{}", method.label());
        assert_eq!(restored.method, method);
        assert_eq!(restored.drains, sess.drains);
        // Zero index-rebuild work on the restored session (the acceptance
        // criterion): no maintenance job of any kind has run.
        assert_eq!(
            restored.maint.stats.swaps,
            0,
            "{}: restore did maintenance work",
            method.label()
        );
        // Searches over the restored session are bit-identical.
        if method != Method::StreamingLlm {
            let probe: Vec<f32> = sess.caches[0][0].key(200).to_vec();
            for h in 0..eng.spec().q_heads {
                let a = sess.retrievers[0][h].retrieve(&probe, 16);
                let b = restored.retrievers[0][h].retrieve(&probe, 16);
                assert_eq!(a.ids, b.ids, "{}: head {h} diverged", method.label());
            }
        }
        // And the next tokens are identical to the never-snapshotted run.
        let mut tok_a = 5u32;
        let mut tok_b = 5u32;
        for step in 0..4 {
            tok_a = eng.decode_step(&mut sess, tok_a).unwrap().token;
            tok_b = eng.decode_step(&mut restored, tok_b).unwrap().token;
            assert_eq!(tok_a, tok_b, "{}: diverged at step {step}", method.label());
        }
        assert_eq!(
            restored.maint.stats.swaps,
            0,
            "{}: decode triggered index work",
            method.label()
        );
        sess.shutdown_maintenance();
        restored.shutdown_maintenance();
    }
}

#[test]
fn engine_snapshot_survives_reclamation_generation() {
    // Engine-level variant of the generation-bump property: evict +
    // reclaim until the store generation bumps, snapshot, restore, and
    // require bit-identical retrieval + continued decodability.
    let mut cfg = engine_cfg(Method::RetrievalAttention);
    cfg.retrieval.maintenance.drain_watermark = 16;
    cfg.retrieval.eviction.max_indexed = 128;
    cfg.retrieval.eviction.reclaim_ratio = 0.25;
    let eng = Engine::from_config(cfg).expect("engine init");
    let mut rng = Rng::seed_from(47);
    let s = tasks::passkey(&mut rng, 600, 0.5);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let _ = eng.generate(&mut sess, 30).unwrap();
    sess.flush_maintenance();
    assert!(sess.maint.stats.reclaims > 0, "setup: no generation bump happened");
    let gen = sess.groups[0][0].store_generation();
    assert!(gen > 0);

    let mut buf: Vec<u8> = Vec::new();
    eng.snapshot_session(&mut sess, &mut buf).unwrap();
    let mut src = buf.as_slice();
    let mut restored = eng.restore_session(&mut src).unwrap();
    assert_eq!(restored.groups[0][0].store_generation(), gen, "generation lost");
    let probe: Vec<f32> = sess.caches[0][0].key(300).to_vec();
    for h in 0..eng.spec().q_heads {
        let a = sess.retrievers[0][h].retrieve(&probe, 16);
        let b = restored.retrievers[0][h].retrieve(&probe, 16);
        assert_eq!(a.ids, b.ids, "head {h} diverged across generation snapshot");
    }
    let out = eng.decode_step(&mut restored, 5).unwrap();
    assert!((out.token as usize) < eng.spec().vocab);
    sess.shutdown_maintenance();
    restored.shutdown_maintenance();
}

fn serving_cfg(max_resident_bytes: usize) -> ServeConfig {
    let mut cfg = engine_cfg(Method::RetrievalAttention);
    cfg.serving.session_cache.max_resident_bytes = max_resident_bytes;
    cfg
}

#[test]
fn multi_turn_through_disk_matches_always_resident() {
    // The acceptance path: turns >= 2 skip prefill entirely (decode-extend
    // over the retained session), including when the session was parked to
    // disk in between — and the tokens are identical either way.
    let disk = Replica::spawn(serving_cfg(0)); // every finished turn parks
    let ram = Replica::spawn(serving_cfg(1 << 40)); // never parks
    let mut rng = Rng::seed_from(61);
    let s = tasks::passkey(&mut rng, 700, 0.4);
    let turns: Vec<Vec<u32>> = vec![s.prompt.clone(), vec![3, 1, 4, 1, 5], vec![9, 2, 6]];

    let run = |rep: &Replica, expect_disk: bool| -> Vec<Vec<u32>> {
        let mut outs = Vec::new();
        for (i, turn) in turns.iter().enumerate() {
            let mode = if i == 0 { SessionMode::Open } else { SessionMode::Continue };
            let rx = rep.submit(Request {
                id: i as u64 + 1,
                prompt: turn.clone(),
                max_tokens: 3,
                session: Some(SessionSpec { session_id: 42, mode }),
            });
            let (tokens, m) = collect(&rx).unwrap();
            assert_eq!(tokens.len(), 3, "turn {i}");
            assert_eq!(m.prompt_tokens, turn.len());
            if i == 0 {
                assert!(!m.resumed_from_disk);
            } else {
                assert_eq!(m.resumed_from_disk, expect_disk, "turn {i}");
                if expect_disk {
                    assert!(m.snapshot_bytes > 0, "turn {i}: no snapshot bytes reported");
                    assert!(m.resume_s >= 0.0);
                    assert!(m.session_parks >= i as u64, "turn {i}: parks not counted");
                    assert!(m.session_resumes >= i as u64, "turn {i}: resumes not counted");
                }
            }
            outs.push(tokens);
        }
        outs
    };

    let a = run(&disk, true);
    let b = run(&ram, false);
    assert_eq!(a, b, "disk-spilled conversation diverged from resident run");

    // First turn solved the task in both runs (sanity: these are real
    // decodes, not replays).
    assert!(s.passed(&a[0]), "turn 1 wrong: {:?} want {:?}", a[0], s.expect);

    // Close both; a second close reports unknown.
    for rep in [&disk, &ram] {
        let rx = rep.submit(Request {
            id: 99,
            prompt: vec![],
            max_tokens: 0,
            session: Some(SessionSpec { session_id: 42, mode: SessionMode::Close }),
        });
        let (tokens, _) = collect(&rx).unwrap();
        assert!(tokens.is_empty());
        let rx = rep.submit(Request {
            id: 100,
            prompt: vec![],
            max_tokens: 0,
            session: Some(SessionSpec { session_id: 42, mode: SessionMode::Close }),
        });
        assert!(collect(&rx).is_err(), "double close must report unknown session");
    }
    // Continuing an unknown session fails cleanly too.
    let rx = disk.submit(Request {
        id: 101,
        prompt: vec![1, 2],
        max_tokens: 1,
        session: Some(SessionSpec { session_id: 777, mode: SessionMode::Continue }),
    });
    assert!(collect(&rx).is_err());
}

#[test]
fn v2_snapshot_restores_into_v3_engine() {
    // Cross-version compatibility: a v2 snapshot (same payload as v3, no
    // checksummed footer) written by the current engine restores under
    // the v3 read-compat path with its policy intact, and keeps decoding
    // bit-identically to the never-snapshotted session. Anything older
    // than v2 is refused on both the write and the read side.
    let eng = Engine::from_config(engine_cfg(Method::RetrievalAttention)).expect("engine init");
    let mut rng = Rng::seed_from(83);
    let s = tasks::passkey(&mut rng, 700, 0.35);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let _ = eng.generate(&mut sess, 2).unwrap();

    let mut v2: Vec<u8> = Vec::new();
    eng.snapshot_session_versioned(&mut sess, &mut v2, retrieval_attention::store::V2).unwrap();
    let mut v3: Vec<u8> = Vec::new();
    eng.snapshot_session(&mut sess, &mut v3).unwrap();
    // v3 = v2 payload + the 20-byte checksummed footer, byte-identical
    // up to the trailer (what makes the read-compat path free).
    assert_eq!(v3.len(), v2.len() + 20, "footer is exactly the trailer");
    assert_eq!(&v3[..v2.len()], &v2[..], "v3 payload diverged from v2");

    let mut src = v2.as_slice();
    let mut restored = eng.restore_session(&mut src).unwrap();
    assert_eq!(restored.len, sess.len);
    assert_eq!(restored.policy, sess.policy, "policy section lost on the v2 read path");
    let mut tok_a = 5u32;
    let mut tok_b = 5u32;
    for step in 0..4 {
        tok_a = eng.decode_step(&mut sess, tok_a).unwrap().token;
        tok_b = eng.decode_step(&mut restored, tok_b).unwrap().token;
        assert_eq!(tok_a, tok_b, "v2-restored session diverged at step {step}");
    }

    // Version policy, both directions: v1 is no longer writable, and a
    // v1-stamped stream is refused on read (the caller re-prefills).
    let mut refused = Vec::new();
    let err = eng
        .snapshot_session_versioned(&mut sess, &mut refused, 1)
        .expect_err("v1 write must be refused");
    assert!(err.to_string().contains("cannot write"), "unexpected: {err}");
    let mut v1_stamped = v2.clone();
    v1_stamped[4..8].copy_from_slice(&1u32.to_le_bytes());
    let err = eng
        .restore_session(&mut v1_stamped.as_slice())
        .expect_err("v1 read must be refused");
    assert!(format!("{err:#}").contains("version policy"), "unexpected: {err:#}");
    sess.shutdown_maintenance();
    restored.shutdown_maintenance();
}

#[test]
fn v3_snapshot_carries_streaming_heads_and_detects_corruption() {
    // A mixed-policy session round-trips its per-head assignment through
    // the policy section, streaming heads shrink the snapshot (their
    // index state is never written), and the v3 footer catches payload
    // corruption that still parses structurally.
    use retrieval_attention::policy::PolicyMode;
    let mut cfg = engine_cfg(Method::RetrievalAttention);
    // Low watermark so the indexed tier actually holds drained rows and
    // the streaming head's index-free snapshot shows up as saved bytes.
    cfg.retrieval.maintenance.drain_watermark = 16;
    let mut scfg = cfg.clone();
    scfg.policy.mode = PolicyMode::Static;
    scfg.policy.force_streaming = vec![(1, 0)];
    scfg.policy.sinks = 8;
    scfg.policy.window = 32;

    let mut rng = Rng::seed_from(89);
    let s = tasks::passkey(&mut rng, 700, 0.45);
    let eng = Engine::from_config(cfg).expect("engine init");
    let seng = Engine::from_config(scfg).expect("engine init");
    let mut plain = eng.prefill(&s.prompt).unwrap();
    let mut mixed = seng.prefill(&s.prompt).unwrap();
    let _ = eng.generate(&mut plain, 4).unwrap();
    let _ = seng.generate(&mut mixed, 4).unwrap();
    assert_eq!(mixed.streaming_fraction(), 0.5);

    let mut pbuf: Vec<u8> = Vec::new();
    let mut mbuf: Vec<u8> = Vec::new();
    eng.snapshot_session(&mut plain, &mut pbuf).unwrap();
    seng.snapshot_session(&mut mixed, &mut mbuf).unwrap();
    // The streaming head persists as a 17-byte stub instead of a full
    // index: the mixed session's snapshot must be strictly smaller.
    assert!(
        mbuf.len() < pbuf.len(),
        "streaming head did not shrink the snapshot: {} >= {}",
        mbuf.len(),
        pbuf.len()
    );

    let mut src = mbuf.as_slice();
    let mut restored = seng.restore_session(&mut src).unwrap();
    assert_eq!(restored.streaming_fraction(), 0.5, "policy section lost in round-trip");
    assert_eq!(restored.policy, mixed.policy);
    // And it keeps decoding identically to the live mixed session.
    let mut tok_a = 5u32;
    let mut tok_b = 5u32;
    for step in 0..4 {
        tok_a = seng.decode_step(&mut mixed, tok_a).unwrap().token;
        tok_b = seng.decode_step(&mut restored, tok_b).unwrap().token;
        assert_eq!(tok_a, tok_b, "mixed-policy restore diverged at step {step}");
    }

    // The footer catches corruption the structural parse would accept:
    // flip one bit in a float field mid-payload — every field still
    // parses, but the checksum verify at the end refuses the restore.
    let mut corrupt = mbuf.clone();
    let mid = corrupt.len() / 2;
    corrupt[mid] ^= 0x01;
    let r = seng.restore_session(&mut corrupt.as_slice());
    assert!(r.is_err(), "bit-flipped snapshot must not restore");
    plain.shutdown_maintenance();
    mixed.shutdown_maintenance();
    restored.shutdown_maintenance();
}

#[test]
fn corrupted_spill_files_quarantine_cleanly_under_fuzz() {
    // The durable-tier corruption contract, fuzzed: take one real parked
    // snapshot and damage it every way a disk can — single bit flips
    // sampled across the whole file (header, payload, footer) and
    // truncations at structural boundaries. Every case must (a) still be
    // re-registered by the boot scan (integrity is proven lazily, on
    // resume), (b) fail `take` with a clean quarantine error — no panic,
    // no half-restored session, (c) preserve the damaged bytes under
    // `.corrupt` for diagnosis, and (d) drop the id from the registry so
    // the next turn gets a definitive miss instead of a retry loop on a
    // file that can never restore. The untouched snapshot must still
    // resume afterwards — the fuzz must not have been "passing" because
    // the baseline itself was broken.
    use retrieval_attention::config::SessionCacheConfig;
    use retrieval_attention::store::SessionCache;

    let eng = Engine::from_config(engine_cfg(Method::RetrievalAttention)).expect("engine init");
    let mut rng = Rng::seed_from(97);
    let s = tasks::passkey(&mut rng, 600, 0.4);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let _ = eng.generate(&mut sess, 2).unwrap();

    let dir = std::env::temp_dir().join(format!("ra-quarantine-fuzz-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cc = SessionCacheConfig {
        max_resident_bytes: 0, // park immediately
        spill_dir: dir.to_string_lossy().into_owned(),
        ephemeral_spill: false, // durable: files outlive the cache
        ..SessionCacheConfig::default()
    };

    // Park once to produce the clean on-disk snapshot, then work from its
    // bytes — each fuzz case rebuilds the directory from scratch.
    let clean = {
        let mut cache = SessionCache::new(cc.clone());
        cache.insert(&eng, 5, sess).expect("park must succeed");
        assert_eq!(cache.parked_count(), 1);
        std::fs::read(dir.join("session-5.ras")).expect("parked snapshot must exist")
    };
    let n = clean.len();
    assert!(n > 64, "snapshot implausibly small: {n}");

    // Case list: bit flips sampled evenly across the file (varying which
    // bit, so zero-byte runs and low/high bits both get coverage), plus
    // truncations at the header, early/mid payload, and footer edges.
    let mut cases: Vec<(String, Vec<u8>)> = Vec::new();
    for off in (0..n).step_by((n / 23).max(1)) {
        let mut bytes = clean.clone();
        bytes[off] ^= 1 << (off % 8);
        cases.push((format!("flip@{off}"), bytes));
    }
    for cut in [0usize, 1, 4, 8, n / 3, n / 2, n - 21, n - 1] {
        cases.push((format!("trunc@{cut}"), clean[..cut].to_vec()));
    }

    let ras = dir.join("session-5.ras");
    let corrupt_path = dir.join("session-5.ras.corrupt");
    for (tag, bytes) in &cases {
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&ras, bytes).unwrap();

        let mut cache = SessionCache::new(cc.clone());
        assert_eq!(cache.stats.recovered, 1, "{tag}: boot scan must register by name");
        assert!(cache.contains(5), "{tag}");

        let err = match cache.take(&eng, 5) {
            Err(e) => e,
            Ok(_) => panic!("{tag}: corrupt snapshot must not restore"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("quarantined"), "{tag}: not a quarantine error: {msg}");
        assert_eq!(cache.stats.quarantines, 1, "{tag}");
        // The damaged file moved aside bit-for-bit; the live name is gone.
        assert!(!ras.exists(), "{tag}: corrupt file left under its live name");
        let kept = std::fs::read(&corrupt_path)
            .unwrap_or_else(|e| panic!("{tag}: no .corrupt file: {e}"));
        assert_eq!(&kept, bytes, "{tag}: quarantine altered the evidence");
        // Registry state: definitive miss from here on, zero disk bytes.
        assert!(!cache.contains(5), "{tag}: quarantined id still registered");
        assert!(cache.take(&eng, 5).unwrap().is_none(), "{tag}: second take must miss");
        assert_eq!(cache.disk_bytes(), 0, "{tag}: disk accounting leaked");
    }

    // Baseline sanity: the clean bytes still restore and decode.
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(&ras, &clean).unwrap();
    let mut cache = SessionCache::new(cc.clone());
    let r = cache
        .take(&eng, 5)
        .expect("clean snapshot must restore")
        .expect("clean snapshot must be registered");
    assert!(r.from_disk);
    assert_eq!(r.snapshot_bytes, n as u64);
    let mut resumed = r.sess;
    let out = eng.decode_step(&mut resumed, 5).unwrap();
    assert!((out.token as usize) < eng.spec().vocab);
    resumed.shutdown_maintenance();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn disk_exhaustion_rejects_with_backpressure() {
    let mut cfg = serving_cfg(0);
    cfg.serving.session_cache.max_disk_bytes = 64; // nothing fits
    let rep = Replica::spawn(cfg);
    let mut rng = Rng::seed_from(71);
    let s = tasks::passkey(&mut rng, 400, 0.5);
    let rx = rep.submit(Request {
        id: 1,
        prompt: s.prompt.clone(),
        max_tokens: 2,
        session: Some(SessionSpec { session_id: 1, mode: SessionMode::Open }),
    });
    let err = collect(&rx).expect_err("park past the disk budget must backpressure");
    assert!(
        err.to_string().contains("backpressure"),
        "unexpected error: {err}"
    );
    // The replica stays healthy for sessionless requests.
    let rx = rep.submit(Request { id: 2, prompt: s.prompt, max_tokens: 1, session: None });
    assert!(collect(&rx).is_ok());
}
