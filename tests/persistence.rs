//! Persistence-subsystem tests: the session snapshot format and the
//! multi-turn session registry.
//!
//! The load-bearing claims, each pinned here:
//!
//! 1. **Bit-identical search**: a head serialized and restored returns
//!    exactly the ids (and scan counts) of the live head, for all four
//!    index families, with the quantized scan tier off and on, and across
//!    a reclamation-generation bump.
//! 2. **No re-prefill, no index rebuild**: an engine-level snapshot
//!    round-trips a decodable session whose maintenance stats start at
//!    zero (nothing was rebuilt) and whose subsequent tokens are
//!    identical to the never-snapshotted session's.
//! 3. **Disk transparency**: a multi-turn conversation forced through
//!    disk on every turn (`max_resident_bytes = 0`) produces
//!    token-identical output to the always-resident run, and exhausting
//!    `max_disk_bytes` rejects with backpressure instead of losing state.

use retrieval_attention::baselines::{
    build_retriever, restore_retriever, GroupShared, HostRetriever, RetrieverInputs,
};
use retrieval_attention::config::{Method, QuantConfig, RetrievalConfig, ServeConfig};
use retrieval_attention::coordinator::{collect, Replica, Request, SessionMode, SessionSpec};
use retrieval_attention::index::{KeyStore, RemapPlan};
use retrieval_attention::kernel::QuantMode;
use retrieval_attention::kvcache::StaticPattern;
use retrieval_attention::model::Engine;
use retrieval_attention::store::codec::{SnapReader, SnapWriter};
use retrieval_attention::tensor::Matrix;
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::tasks;
use std::sync::Arc;

const INDEX_METHODS: [Method; 4] =
    [Method::Flat, Method::Ivf, Method::Hnsw, Method::RetrievalAttention];

fn head_setup(
    quant: QuantMode,
    seed: u64,
) -> (KeyStore, Vec<u32>, Matrix, RetrievalConfig) {
    let mut rng = Rng::seed_from(seed);
    let d = 16usize;
    let n = 512usize;
    let keys = KeyStore::from_matrix(Matrix::from_fn(n, d, |_, _| rng.normal()));
    let ids: Vec<u32> = (0..n as u32).map(|i| i + 100).collect();
    let queries =
        Matrix::from_fn(64, d, |_, c| rng.normal() + if c < d / 4 { 1.0 } else { 0.0 });
    let mut cfg = RetrievalConfig::default();
    cfg.ef = 64;
    cfg.quant = QuantConfig { mode: quant, rerank: 2 };
    (keys, ids, queries, cfg)
}

fn save_head(head: &dyn HostRetriever) -> Vec<u8> {
    let mut buf: Vec<u8> = Vec::new();
    let mut w = SnapWriter::new(&mut buf);
    head.save_state(&mut w).expect("head must serialize");
    buf
}

fn restore_head(buf: &[u8], group: Arc<GroupShared>) -> Box<dyn HostRetriever> {
    let mut src = buf;
    let mut r = SnapReader::new(&mut src);
    restore_retriever(&mut r, group).expect("head must restore")
}

fn assert_bit_identical(
    a: &dyn HostRetriever,
    b: &dyn HostRetriever,
    queries: &Matrix,
    k: usize,
    tag: &str,
) {
    for qi in 0..queries.rows() {
        let q = queries.row(qi);
        let ra = a.retrieve(q, k);
        let rb = b.retrieve(q, k);
        assert_eq!(ra.ids, rb.ids, "{tag}: query {qi} ids diverged");
        assert_eq!(ra.scanned, rb.scanned, "{tag}: query {qi} scan count diverged");
    }
}

#[test]
fn head_snapshot_roundtrip_bit_identical_all_families_and_quant() {
    for (mi, method) in INDEX_METHODS.into_iter().enumerate() {
        for (qi, quant) in [QuantMode::Off, QuantMode::Fp16, QuantMode::Int8]
            .into_iter()
            .enumerate()
        {
            let (keys, ids, queries, cfg) =
                head_setup(quant, 1000 + (mi * 3 + qi) as u64);
            let inp =
                RetrieverInputs::from_parts(keys, ids.clone(), &queries, 0.25, &cfg, 7);
            let group = inp.group.clone();
            let head = build_retriever(method, inp);
            // Tombstone a band so the snapshot carries real deletion state.
            assert!(head.remove_batch(&ids[40..96]));
            let buf = save_head(head.as_ref());
            // The group round-trips through the same format.
            let mut gbuf: Vec<u8> = Vec::new();
            {
                let mut w = SnapWriter::new(&mut gbuf);
                retrieval_attention::store::save_group(&mut w, &group).unwrap();
            }
            let mut gsrc = gbuf.as_slice();
            let mut gr = SnapReader::new(&mut gsrc);
            let restored_group = retrieval_attention::store::load_group(&mut gr).unwrap();
            let restored = restore_head(&buf, restored_group);
            let tag = format!("{}/{:?}", method.label(), quant);
            assert_eq!(restored.name(), head.name(), "{tag}: label diverged");
            assert_eq!(restored.tombstones(), head.tombstones(), "{tag}");
            assert_eq!(restored.indexed_len(), head.indexed_len(), "{tag}");
            assert_bit_identical(head.as_ref(), restored.as_ref(), &queries, 20, &tag);
        }
    }
}

#[test]
fn head_snapshot_across_reclamation_generation_bump() {
    // Snapshot taken AFTER a reclamation epoch: dense ids were renumbered
    // under a bumped store generation; the snapshot must carry the
    // compacted store, the generation-stamped map, and fronts whose
    // searches stay bit-identical after restore.
    for (mi, method) in INDEX_METHODS.into_iter().enumerate() {
        let (keys, ids, queries, cfg) = head_setup(QuantMode::Int8, 2000 + mi as u64);
        let inp = RetrieverInputs::from_parts(keys, ids.clone(), &queries, 0.25, &cfg, 11);
        let group = inp.group.clone();
        let head = build_retriever(method, inp);
        assert!(head.remove_batch(&ids[..128]));
        assert!(head.supports_reclaim(), "{}: no reclaim support", method.label());
        // The production epoch flow: plan from the head's dead set,
        // publish map -> store, remap the front, release the old map.
        let dead = head.dense_dead_ids();
        let old_map = group.id_map();
        let gen = old_map.store_gen + 1;
        let (plan, keep) =
            RemapPlan::from_dead(&dead, &group.keys(), gen).expect("plan must build");
        let new_ids: Vec<u32> = keep.iter().map(|&o| old_map.ids[o as usize]).collect();
        let new_store = plan.store.clone();
        let plan = Arc::new(plan);
        group.publish_remap(new_ids, new_store, gen);
        assert!(head.apply_remap(&plan), "{}: remap refused", method.label());
        group.finish_remap();
        assert_eq!(group.store_generation(), gen);
        assert_eq!(head.tombstones(), 0);

        let buf = save_head(head.as_ref());
        let mut gbuf: Vec<u8> = Vec::new();
        {
            let mut w = SnapWriter::new(&mut gbuf);
            retrieval_attention::store::save_group(&mut w, &group).unwrap();
        }
        let mut gsrc = gbuf.as_slice();
        let mut gr = SnapReader::new(&mut gsrc);
        let restored_group = retrieval_attention::store::load_group(&mut gr).unwrap();
        assert_eq!(restored_group.store_generation(), gen, "generation lost in snapshot");
        let restored = restore_head(&buf, restored_group.clone());
        let tag = format!("{}/post-reclaim", method.label());
        assert_bit_identical(head.as_ref(), restored.as_ref(), &queries, 20, &tag);
        // The restored head keeps working online: a drain-style insert
        // against the restored group lands and retrieves.
        let grown = restored_group.extend(
            Matrix::from_fn(1, 16, |_, c| if c == 0 { 9.0 } else { 0.0 }),
            &[5000],
            true,
        );
        assert!(restored.insert_batch(
            &grown,
            &[5000],
            &retrieval_attention::index::InsertContext::none()
        ));
        let mut probe = vec![0.0f32; 16];
        probe[0] = 1.0;
        let out = restored.retrieve(&probe, 4);
        assert!(out.ids.contains(&5000), "{tag}: post-restore insert lost: {:?}", out.ids);
    }
}

#[test]
fn cow_fork_shares_frozen_state_and_diverges_on_write() {
    let (keys, ids, queries, cfg) = head_setup(QuantMode::Off, 3000);
    let inp = RetrieverInputs::from_parts(keys, ids.clone(), &queries, 0.25, &cfg, 13);
    let group = inp.group.clone();
    let head = build_retriever(Method::RetrievalAttention, inp);
    let forked_group = group.fork();
    assert_eq!(forked_group.store_generation(), group.store_generation());
    let fork = head.fork_with_group(forked_group.clone()).expect("index heads fork");
    assert_bit_identical(head.as_ref(), fork.as_ref(), &queries, 20, "fork");
    // A write on the BASE (drain-style insert) must not leak into the fork.
    let grown = group.extend(
        Matrix::from_fn(1, 16, |_, c| if c == 1 { 9.0 } else { 0.0 }),
        &[7000],
        true,
    );
    assert!(head.insert_batch(&grown, &[7000], &retrieval_attention::index::InsertContext::none()));
    let mut probe = vec![0.0f32; 16];
    probe[1] = 1.0;
    assert!(head.retrieve(&probe, 4).ids.contains(&7000), "base lost its own insert");
    assert!(
        !fork.retrieve(&probe, 64).ids.contains(&7000),
        "base write leaked into the fork"
    );
    // And the fork keeps its own write path.
    let fgrown = forked_group.extend(
        Matrix::from_fn(1, 16, |_, c| if c == 2 { 9.0 } else { 0.0 }),
        &[8000],
        true,
    );
    let ctx = retrieval_attention::index::InsertContext::none();
    assert!(fork.insert_batch(&fgrown, &[8000], &ctx));
    let mut probe2 = vec![0.0f32; 16];
    probe2[2] = 1.0;
    assert!(fork.retrieve(&probe2, 4).ids.contains(&8000), "fork lost its own insert");
    assert!(
        !head.retrieve(&probe2, 64).ids.contains(&8000),
        "fork write leaked into the base"
    );
}

fn engine_cfg(method: Method) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = method;
    cfg.pattern = StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.ef = 64;
    // Deterministic token streams: maintenance inline, and a watermark
    // high enough that the short decodes below never drain — the restored
    // session must show ZERO maintenance work (no rebuild, no insert).
    cfg.retrieval.maintenance.async_worker = false;
    cfg.retrieval.maintenance.drain_watermark = 1024;
    cfg
}

#[test]
fn engine_snapshot_roundtrip_decodes_identically() {
    // All four index families + the two trivially-persistable policies +
    // one rebuild-on-restore baseline (SnapKV: heads can't serialize, but
    // the snapshot's caches/queries rebuild them deterministically).
    for method in [
        Method::RetrievalAttention,
        Method::Flat,
        Method::Ivf,
        Method::Hnsw,
        Method::Full,
        Method::StreamingLlm,
        Method::SnapKv,
    ] {
        let eng = Engine::from_config(engine_cfg(method)).expect("engine init");
        let mut rng = Rng::seed_from(31);
        let s = tasks::passkey(&mut rng, 700, 0.3);
        let mut sess = eng.prefill(&s.prompt).unwrap();
        let (_, _) = eng.generate(&mut sess, 2).unwrap();

        let mut buf: Vec<u8> = Vec::new();
        let bytes = eng.snapshot_session(&mut sess, &mut buf).unwrap();
        assert_eq!(bytes, buf.len() as u64, "byte accounting diverged");
        assert!(bytes > 0);
        let mut src = buf.as_slice();
        let mut restored = eng.restore_session(&mut src).unwrap();

        assert_eq!(restored.len, sess.len, "{}", method.label());
        assert_eq!(restored.method, method);
        assert_eq!(restored.drains, sess.drains);
        // Zero index-rebuild work on the restored session (the acceptance
        // criterion): no maintenance job of any kind has run.
        assert_eq!(
            restored.maint.stats.swaps,
            0,
            "{}: restore did maintenance work",
            method.label()
        );
        // Searches over the restored session are bit-identical.
        if method != Method::StreamingLlm {
            let probe: Vec<f32> = sess.caches[0][0].key(200).to_vec();
            for h in 0..eng.spec().q_heads {
                let a = sess.retrievers[0][h].retrieve(&probe, 16);
                let b = restored.retrievers[0][h].retrieve(&probe, 16);
                assert_eq!(a.ids, b.ids, "{}: head {h} diverged", method.label());
            }
        }
        // And the next tokens are identical to the never-snapshotted run.
        let mut tok_a = 5u32;
        let mut tok_b = 5u32;
        for step in 0..4 {
            tok_a = eng.decode_step(&mut sess, tok_a).unwrap().token;
            tok_b = eng.decode_step(&mut restored, tok_b).unwrap().token;
            assert_eq!(tok_a, tok_b, "{}: diverged at step {step}", method.label());
        }
        assert_eq!(
            restored.maint.stats.swaps,
            0,
            "{}: decode triggered index work",
            method.label()
        );
        sess.shutdown_maintenance();
        restored.shutdown_maintenance();
    }
}

#[test]
fn engine_snapshot_survives_reclamation_generation() {
    // Engine-level variant of the generation-bump property: evict +
    // reclaim until the store generation bumps, snapshot, restore, and
    // require bit-identical retrieval + continued decodability.
    let mut cfg = engine_cfg(Method::RetrievalAttention);
    cfg.retrieval.maintenance.drain_watermark = 16;
    cfg.retrieval.eviction.max_indexed = 128;
    cfg.retrieval.eviction.reclaim_ratio = 0.25;
    let eng = Engine::from_config(cfg).expect("engine init");
    let mut rng = Rng::seed_from(47);
    let s = tasks::passkey(&mut rng, 600, 0.5);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let _ = eng.generate(&mut sess, 30).unwrap();
    sess.flush_maintenance();
    assert!(sess.maint.stats.reclaims > 0, "setup: no generation bump happened");
    let gen = sess.groups[0][0].store_generation();
    assert!(gen > 0);

    let mut buf: Vec<u8> = Vec::new();
    eng.snapshot_session(&mut sess, &mut buf).unwrap();
    let mut src = buf.as_slice();
    let mut restored = eng.restore_session(&mut src).unwrap();
    assert_eq!(restored.groups[0][0].store_generation(), gen, "generation lost");
    let probe: Vec<f32> = sess.caches[0][0].key(300).to_vec();
    for h in 0..eng.spec().q_heads {
        let a = sess.retrievers[0][h].retrieve(&probe, 16);
        let b = restored.retrievers[0][h].retrieve(&probe, 16);
        assert_eq!(a.ids, b.ids, "head {h} diverged across generation snapshot");
    }
    let out = eng.decode_step(&mut restored, 5).unwrap();
    assert!((out.token as usize) < eng.spec().vocab);
    sess.shutdown_maintenance();
    restored.shutdown_maintenance();
}

fn serving_cfg(max_resident_bytes: usize) -> ServeConfig {
    let mut cfg = engine_cfg(Method::RetrievalAttention);
    cfg.serving.session_cache.max_resident_bytes = max_resident_bytes;
    cfg
}

#[test]
fn multi_turn_through_disk_matches_always_resident() {
    // The acceptance path: turns >= 2 skip prefill entirely (decode-extend
    // over the retained session), including when the session was parked to
    // disk in between — and the tokens are identical either way.
    let disk = Replica::spawn(serving_cfg(0)); // every finished turn parks
    let ram = Replica::spawn(serving_cfg(1 << 40)); // never parks
    let mut rng = Rng::seed_from(61);
    let s = tasks::passkey(&mut rng, 700, 0.4);
    let turns: Vec<Vec<u32>> = vec![s.prompt.clone(), vec![3, 1, 4, 1, 5], vec![9, 2, 6]];

    let run = |rep: &Replica, expect_disk: bool| -> Vec<Vec<u32>> {
        let mut outs = Vec::new();
        for (i, turn) in turns.iter().enumerate() {
            let mode = if i == 0 { SessionMode::Open } else { SessionMode::Continue };
            let rx = rep.submit(Request {
                id: i as u64 + 1,
                prompt: turn.clone(),
                max_tokens: 3,
                session: Some(SessionSpec { session_id: 42, mode }),
            });
            let (tokens, m) = collect(&rx).unwrap();
            assert_eq!(tokens.len(), 3, "turn {i}");
            assert_eq!(m.prompt_tokens, turn.len());
            if i == 0 {
                assert!(!m.resumed_from_disk);
            } else {
                assert_eq!(m.resumed_from_disk, expect_disk, "turn {i}");
                if expect_disk {
                    assert!(m.snapshot_bytes > 0, "turn {i}: no snapshot bytes reported");
                    assert!(m.resume_s >= 0.0);
                    assert!(m.session_parks >= i as u64, "turn {i}: parks not counted");
                    assert!(m.session_resumes >= i as u64, "turn {i}: resumes not counted");
                }
            }
            outs.push(tokens);
        }
        outs
    };

    let a = run(&disk, true);
    let b = run(&ram, false);
    assert_eq!(a, b, "disk-spilled conversation diverged from resident run");

    // First turn solved the task in both runs (sanity: these are real
    // decodes, not replays).
    assert!(s.passed(&a[0]), "turn 1 wrong: {:?} want {:?}", a[0], s.expect);

    // Close both; a second close reports unknown.
    for rep in [&disk, &ram] {
        let rx = rep.submit(Request {
            id: 99,
            prompt: vec![],
            max_tokens: 0,
            session: Some(SessionSpec { session_id: 42, mode: SessionMode::Close }),
        });
        let (tokens, _) = collect(&rx).unwrap();
        assert!(tokens.is_empty());
        let rx = rep.submit(Request {
            id: 100,
            prompt: vec![],
            max_tokens: 0,
            session: Some(SessionSpec { session_id: 42, mode: SessionMode::Close }),
        });
        assert!(collect(&rx).is_err(), "double close must report unknown session");
    }
    // Continuing an unknown session fails cleanly too.
    let rx = disk.submit(Request {
        id: 101,
        prompt: vec![1, 2],
        max_tokens: 1,
        session: Some(SessionSpec { session_id: 777, mode: SessionMode::Continue }),
    });
    assert!(collect(&rx).is_err());
}

#[test]
fn v1_snapshot_restores_into_v2_engine_as_all_retrieval() {
    // Cross-version compatibility: a v1 snapshot (no per-head policy
    // section) written by the current engine restores under the v2 read
    // path with every head on the retrieval tier, and keeps decoding
    // bit-identically to the never-snapshotted session.
    let eng = Engine::from_config(engine_cfg(Method::RetrievalAttention)).expect("engine init");
    let mut rng = Rng::seed_from(83);
    let s = tasks::passkey(&mut rng, 700, 0.35);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let _ = eng.generate(&mut sess, 2).unwrap();

    let mut v1: Vec<u8> = Vec::new();
    eng.snapshot_session_versioned(&mut sess, &mut v1, retrieval_attention::store::V1).unwrap();
    let mut v2: Vec<u8> = Vec::new();
    eng.snapshot_session(&mut sess, &mut v2).unwrap();
    // v2 carries the policy section on top of everything v1 has.
    assert!(v2.len() > v1.len(), "v2 snapshot not larger: {} <= {}", v2.len(), v1.len());

    let mut src = v1.as_slice();
    let mut restored = eng.restore_session(&mut src).unwrap();
    assert_eq!(restored.len, sess.len);
    assert_eq!(restored.streaming_fraction(), 0.0, "v1 restore must be all-retrieval");
    assert_eq!(restored.index_bytes_avoided, 0);
    let mut tok_a = 5u32;
    let mut tok_b = 5u32;
    for step in 0..4 {
        tok_a = eng.decode_step(&mut sess, tok_a).unwrap().token;
        tok_b = eng.decode_step(&mut restored, tok_b).unwrap().token;
        assert_eq!(tok_a, tok_b, "v1-restored session diverged at step {step}");
    }
    sess.shutdown_maintenance();
    restored.shutdown_maintenance();
}

#[test]
fn v2_snapshot_carries_streaming_heads_and_refuses_v1() {
    // A mixed-policy session round-trips its per-head assignment through
    // the v2 policy section — and cannot be written as v1, because tag-4
    // (streaming) retrievers without a policy vector would restore
    // inconsistently.
    use retrieval_attention::policy::PolicyMode;
    let mut cfg = engine_cfg(Method::RetrievalAttention);
    // Low watermark so the indexed tier actually holds drained rows and
    // the streaming head's index-free snapshot shows up as saved bytes.
    cfg.retrieval.maintenance.drain_watermark = 16;
    let mut scfg = cfg.clone();
    scfg.policy.mode = PolicyMode::Static;
    scfg.policy.force_streaming = vec![(1, 0)];
    scfg.policy.sinks = 8;
    scfg.policy.window = 32;

    let mut rng = Rng::seed_from(89);
    let s = tasks::passkey(&mut rng, 700, 0.45);
    let eng = Engine::from_config(cfg).expect("engine init");
    let seng = Engine::from_config(scfg).expect("engine init");
    let mut plain = eng.prefill(&s.prompt).unwrap();
    let mut mixed = seng.prefill(&s.prompt).unwrap();
    let _ = eng.generate(&mut plain, 4).unwrap();
    let _ = seng.generate(&mut mixed, 4).unwrap();
    assert_eq!(mixed.streaming_fraction(), 0.5);

    let mut err = Vec::new();
    let refused = seng.snapshot_session_versioned(&mut mixed, &mut err, retrieval_attention::store::V1);
    assert!(refused.is_err(), "v1 write of a streaming session must be refused");

    let mut pbuf: Vec<u8> = Vec::new();
    let mut mbuf: Vec<u8> = Vec::new();
    eng.snapshot_session(&mut plain, &mut pbuf).unwrap();
    seng.snapshot_session(&mut mixed, &mut mbuf).unwrap();
    // The streaming head persists as a 17-byte stub instead of a full
    // index: the mixed session's snapshot must be strictly smaller.
    assert!(
        mbuf.len() < pbuf.len(),
        "streaming head did not shrink the snapshot: {} >= {}",
        mbuf.len(),
        pbuf.len()
    );

    let mut src = mbuf.as_slice();
    let mut restored = seng.restore_session(&mut src).unwrap();
    assert_eq!(restored.streaming_fraction(), 0.5, "policy section lost in round-trip");
    assert_eq!(restored.policy, mixed.policy);
    // And it keeps decoding identically to the live mixed session.
    let mut tok_a = 5u32;
    let mut tok_b = 5u32;
    for step in 0..4 {
        tok_a = seng.decode_step(&mut mixed, tok_a).unwrap().token;
        tok_b = seng.decode_step(&mut restored, tok_b).unwrap().token;
        assert_eq!(tok_a, tok_b, "mixed-policy restore diverged at step {step}");
    }
    plain.shutdown_maintenance();
    mixed.shutdown_maintenance();
    restored.shutdown_maintenance();
}

#[test]
fn disk_exhaustion_rejects_with_backpressure() {
    let mut cfg = serving_cfg(0);
    cfg.serving.session_cache.max_disk_bytes = 64; // nothing fits
    let rep = Replica::spawn(cfg);
    let mut rng = Rng::seed_from(71);
    let s = tasks::passkey(&mut rng, 400, 0.5);
    let rx = rep.submit(Request {
        id: 1,
        prompt: s.prompt.clone(),
        max_tokens: 2,
        session: Some(SessionSpec { session_id: 1, mode: SessionMode::Open }),
    });
    let err = collect(&rx).expect_err("park past the disk budget must backpressure");
    assert!(
        err.to_string().contains("backpressure"),
        "unexpected error: {err}"
    );
    // The replica stays healthy for sessionless requests.
    let rx = rep.submit(Request { id: 2, prompt: s.prompt, max_tokens: 1, session: None });
    assert!(collect(&rx).is_ok());
}
