//! End-to-end integration: runtime → engine prefill → Algorithm-1 decode,
//! on the hand-constructed induction model.
//!
//! These tests are the keystone of the reproduction: they prove the *task
//! accuracy ⇔ retrieval quality* causal chain the paper's Tables 2/3 rest
//! on. They always run: when `make artifacts` has produced PJRT artifacts
//! the compiled HLO executes, otherwise the runtime's native backend
//! executes the same entry points in Rust — CI can no longer go green on
//! code it never ran.

use retrieval_attention::config::{Method, ServeConfig};
use retrieval_attention::model::Engine;
use retrieval_attention::util::rng::Rng;
use retrieval_attention::workload::tasks;

fn engine(method: Method) -> Engine {
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = method;
    // Scaled-down static pattern so host retrieval matters at test sizes.
    cfg.pattern = retrieval_attention::kvcache::StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.ef = 64;
    Engine::from_config(cfg).expect("engine init")
}

#[test]
fn full_attention_solves_passkey_everywhere() {
    let eng = engine(Method::Full);
    let mut rng = Rng::seed_from(42);
    for depth in [0.05f32, 0.5, 0.95] {
        let s = tasks::passkey(&mut rng, 768, depth);
        let mut sess = eng.prefill(&s.prompt).unwrap();
        let (tokens, _) = eng.generate(&mut sess, s.expect.len()).unwrap();
        assert!(
            s.passed(&tokens),
            "full attention failed at depth {depth}: got {tokens:?}, want {:?}",
            s.expect
        );
    }
}

#[test]
fn retrieval_attention_matches_full_on_kv_retrieval() {
    let eng = engine(Method::RetrievalAttention);
    let mut rng = Rng::seed_from(7);
    let mut pass = 0;
    let n = 5;
    for _ in 0..n {
        let s = tasks::kv_retrieval(&mut rng, 1024, 64);
        let mut sess = eng.prefill(&s.prompt).unwrap();
        let (tokens, _) = eng.generate(&mut sess, s.expect.len()).unwrap();
        if s.passed(&tokens) {
            pass += 1;
        }
        // At this tiny corpus (≈860 indexed keys) the beam necessarily
        // touches a large share; the paper's 1–3% fraction emerges at
        // 128K+ keys and is asserted by the fig6 experiment / benches.
        // Here we only require it to beat a full scan.
        let frac = sess.mean_scanned() / 1024.0;
        assert!(frac < 0.95, "scanned too much: {frac}");
    }
    assert!(pass >= n - 1, "RetrievalAttention solved only {pass}/{n}");
}

#[test]
fn streaming_llm_fails_outside_window() {
    let eng = engine(Method::StreamingLlm);
    let mut rng = Rng::seed_from(9);
    // Needle deep in the discarded middle: StreamingLLM must miss it.
    let s = tasks::passkey(&mut rng, 1024, 0.5);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let (tokens, _) = eng.generate(&mut sess, 2).unwrap();
    assert!(
        s.grade(&tokens) <= 0.5,
        "StreamingLLM should not complete the out-of-window chain (got {tokens:?})"
    );

    // ...but succeeds when the needle is inside the sliding window.
    let s = tasks::passkey(&mut rng, 1024, 0.97);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let (tokens, _) = eng.generate(&mut sess, 2).unwrap();
    assert!(s.passed(&tokens), "StreamingLLM should solve in-window needles");
}

#[test]
fn multi_hop_variable_tracking_with_retrieval() {
    let eng = engine(Method::RetrievalAttention);
    let mut rng = Rng::seed_from(21);
    let mut pass = 0;
    for _ in 0..3 {
        let s = tasks::ruler_variable_tracking(&mut rng, 768, 2);
        let mut sess = eng.prefill(&s.prompt).unwrap();
        let (tokens, _) = eng.generate(&mut sess, s.expect.len()).unwrap();
        if s.passed(&tokens) {
            pass += 1;
        }
    }
    assert!(pass >= 2, "multi-hop tracking solved only {pass}/3");
}

#[test]
fn decode_breakdown_has_all_phases() {
    let eng = engine(Method::RetrievalAttention);
    let mut rng = Rng::seed_from(33);
    let s = tasks::passkey(&mut rng, 900, 0.4);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let out = eng.decode_step(&mut sess, *s.prompt.last().unwrap()).unwrap();
    let bd = out.breakdown;
    assert!(bd.search > 0.0, "no search time recorded");
    assert!(bd.attention > 0.0, "no attention time recorded");
    assert!(bd.other > 0.0, "no other time recorded");
}

#[test]
fn session_tiers_account_every_token() {
    let eng = engine(Method::Flat);
    let mut rng = Rng::seed_from(55);
    let s = tasks::passkey(&mut rng, 700, 0.5);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let _ = eng.generate(&mut sess, 4).unwrap();
    let cache = &sess.caches[0][0];
    assert_eq!(
        cache.len(),
        700 + 3,
        "prompt + decode steps (first + last tokens are not fed back)"
    );
    let dev = cache.device_ids().len();
    let idx = cache.indexed_ids().len();
    let over = cache.overflow_ids().len();
    assert_eq!(dev + idx + over, cache.len());
}

#[test]
fn online_drain_bounds_overflow_and_grows_index() {
    // The tentpole behaviour: long generations must not accumulate an
    // unbounded, linearly-scanned overflow buffer — the engine drains it
    // into the ANN index on the watermark, and the answer chain still
    // resolves afterwards.
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = Method::RetrievalAttention;
    cfg.pattern = retrieval_attention::kvcache::StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.ef = 64;
    cfg.retrieval.maintenance.drain_watermark = 16;
    cfg.retrieval.maintenance.recent_queries = 16;
    let eng = Engine::from_config(cfg).expect("engine init");

    let mut rng = Rng::seed_from(77);
    let s = tasks::passkey(&mut rng, 700, 0.3);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let indexed_before = sess.caches[0][0].indexed_ids().len();
    let (_tokens, bd) = eng.generate(&mut sess, 60).unwrap();

    assert!(sess.drains > 0, "60 generated tokens must trigger watermark-16 drains");
    assert!(sess.drained_tokens >= 32, "drained too little: {}", sess.drained_tokens);
    assert!(bd.maintenance > 0.0, "maintenance phase must be timed");
    for (layer, caches) in sess.caches.iter().enumerate() {
        for (kvh, cache) in caches.iter().enumerate() {
            let over = cache.overflow_ids().len();
            assert!(
                over < 16,
                "layer {layer} kvh {kvh}: overflow {over} not bounded by the watermark"
            );
            // Tiers still partition every token exactly once.
            let mut all: Vec<u32> = cache.device_ids();
            all.extend(cache.indexed_ids());
            all.extend(cache.overflow_ids());
            all.sort_unstable();
            assert_eq!(all, (0..cache.len() as u32).collect::<Vec<u32>>());
        }
    }
    let indexed_after = sess.caches[0][0].indexed_ids().len();
    assert!(
        indexed_after > indexed_before,
        "index must grow past the prefill set ({indexed_before} -> {indexed_after})"
    );
    // The group's shared segmented store grew in lockstep with the
    // indexed tier — and only by appending chunks, never by recopying the
    // prefill prefix.
    assert_eq!(sess.host_store(0, 0).rows(), indexed_after);
    assert!(sess.host_store(0, 0).segment_count() >= 2, "drains must append segments");

    // Drained tokens must actually be *searchable* in the grown index, not
    // just accounted for: probe the retriever with drained keys themselves
    // (self-similarity dominates for the induction model's ±1 codes, so a
    // correctly wired + mapped node must surface its own absolute id).
    let cache = &sess.caches[0][0];
    let drained_lo = indexed_before as u32 + 32; // first drained absolute id
    let drained_hi = cache.indexed_end() as u32;
    assert!(drained_hi > drained_lo, "no drained range to probe");
    let mut hits = 0;
    let probes: Vec<u32> = (drained_lo..drained_hi).step_by(11).take(5).collect();
    for &id in &probes {
        let r = sess.retrievers[0][0].retrieve(cache.key(id as usize), 32);
        if r.ids.contains(&id) {
            hits += 1;
        }
    }
    assert!(
        hits >= probes.len() - 1,
        "drained keys not retrievable from the grown index: {hits}/{} probes hit",
        probes.len()
    );
}

#[test]
fn streaming_eviction_bounds_index_and_unreaches_retired() {
    // Window retirement over the indexed tier: generation past the
    // configured budget must keep every index bounded, and retired tokens
    // must be unreachable both from attention (tier accounting) and from
    // retrieval (tombstoned in the index).
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = Method::RetrievalAttention;
    cfg.pattern = retrieval_attention::kvcache::StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.ef = 64;
    cfg.retrieval.maintenance.drain_watermark = 16;
    cfg.retrieval.maintenance.recent_queries = 16;
    cfg.retrieval.eviction.max_indexed = 256;
    // Reclamation off: this test pins the tombstone-only path (retired
    // rows stay as index tombstones); the reclaim tests below cover the
    // physical-reclamation epochs.
    cfg.retrieval.eviction.reclaim_ratio = 0.0;
    let eng = Engine::from_config(cfg).expect("engine init");

    let mut rng = Rng::seed_from(123);
    let s = tasks::passkey(&mut rng, 700, 0.3);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    // Prefill indexes 700 - 128 - 32 = 540 tokens: already past the cap.
    assert!(sess.caches[0][0].indexed_len() > 256);
    let _ = eng.generate(&mut sess, 40).unwrap();
    sess.shutdown_maintenance();

    assert!(sess.maint.stats.evicted_tokens > 0, "eviction never fired");
    for (layer, caches) in sess.caches.iter().enumerate() {
        for (kvh, cache) in caches.iter().enumerate() {
            // The live indexed tier is bounded by the eviction budget plus
            // at most one drain batch (a batch that lands after the last
            // eviction check is retired on the *next* maintenance pass).
            assert!(
                cache.indexed_len() <= 256 + 16,
                "layer {layer} kvh {kvh}: indexed tier {} not bounded",
                cache.indexed_len()
            );
            assert!(!cache.retired_ids().is_empty(), "nothing retired at layer {layer}");
            // Four tiers partition every token exactly once.
            let mut all: Vec<u32> = cache.device_ids();
            all.extend(cache.indexed_ids());
            all.extend(cache.overflow_ids());
            all.extend(cache.retired_ids());
            all.sort_unstable();
            assert_eq!(all, (0..cache.len() as u32).collect::<Vec<u32>>());
            // Index size reconciles: live == cache's indexed tier; the
            // tombstones account for every retired-and-drained slot.
            let r = &sess.retrievers[layer][kvh];
            assert_eq!(r.indexed_len(), Some(cache.indexed_len()));
        }
    }
    // Retired tokens are unreachable through retrieval: probing with a
    // retired token's own key must not return its id (the induction
    // model's codes make self-retrieval dominant when present).
    let cache = &sess.caches[0][0];
    let retired = cache.retired_ids();
    assert!(retired.len() >= 100);
    for &id in retired.iter().step_by(37).take(8) {
        let out = sess.retrievers[0][0].retrieve(cache.key(id as usize), 32);
        assert!(!out.ids.contains(&id), "retired token {id} still retrievable");
        for got in &out.ids {
            assert!(!cache.is_retired(*got as usize), "retrieval returned retired id {got}");
        }
    }
    assert!(sess.tombstone_ratio() > 0.0, "tombstone ratio must reflect eviction");
}

#[test]
fn reclamation_epoch_shrinks_memory_and_preserves_retrieval() {
    // The tentpole acceptance: after retiring a large fraction of the
    // indexed tier, a reclamation epoch must make the group store + id
    // map + index bytes actually SHRINK (not just tombstone), while live
    // tokens stay retrievable and retired ones stay gone.
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = Method::RetrievalAttention;
    cfg.pattern = retrieval_attention::kvcache::StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.ef = 64;
    cfg.retrieval.maintenance.drain_watermark = 16;
    cfg.retrieval.maintenance.recent_queries = 16;
    cfg.retrieval.eviction.max_indexed = 256;
    cfg.retrieval.eviction.reclaim_ratio = 0.25;
    let eng = Engine::from_config(cfg).expect("engine init");

    let mut rng = Rng::seed_from(321);
    let s = tasks::passkey(&mut rng, 700, 0.3);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    // Prefill indexes 700 - 128 - 32 = 540 rows per group.
    let rows_before = sess.host_store(0, 0).rows();
    assert_eq!(rows_before, 540);
    let bytes_before = sess.index_memory_bytes();
    let group_bytes = |sess: &retrieval_attention::model::Session| -> usize {
        sess.groups.iter().flatten().map(|g| g.store_bytes() + g.map_bytes()).sum()
    };
    let store_before = group_bytes(&sess);
    let _ = eng.generate(&mut sess, 40).unwrap();
    sess.shutdown_maintenance();

    // Eviction retired ≥ 25% of each group's tier (induction-mini has
    // 2 layers × 1 kv head = 2 groups) and at least one epoch ran.
    let groups_total = sess.groups.iter().map(|l| l.len()).sum::<usize>();
    assert!(
        sess.maint.stats.evicted_tokens >= (groups_total as u64) * 135,
        "setup must retire ≥25% per group"
    );
    assert!(sess.maint.stats.reclaims > 0, "no reclamation epoch ran");
    assert!(sess.maint.stats.reclaimed_rows > 0);
    for (layer, caches) in sess.caches.iter().enumerate() {
        for (kvh, cache) in caches.iter().enumerate() {
            let group = &sess.groups[layer][kvh];
            let rows = sess.host_store(layer, kvh).rows();
            assert_eq!(group.id_map().len(), rows, "map/store length diverged");
            assert!(group.store_generation() > 0, "generation never bumped");
            // The store physically shrank: live rows plus the (bounded)
            // tombstones accumulated since the last epoch.
            let live = cache.indexed_len();
            assert!(
                rows <= live + live / 2 + 64,
                "layer {layer} kvh {kvh}: store rows {rows} not reclaimed (live {live})"
            );
            // Head index sizes reconcile with the compacted space.
            let r = &sess.retrievers[layer][kvh];
            assert_eq!(r.indexed_len(), Some(live));
        }
    }
    // Total index memory strictly shrinks, and the group store + id map
    // bytes (the part an epoch physically frees) shrink by at least half
    // the retired fraction.
    let bytes_after = sess.index_memory_bytes();
    assert!(
        bytes_after < bytes_before,
        "index memory did not shrink: {bytes_before} -> {bytes_after}"
    );
    let store_after = group_bytes(&sess);
    let retired_frac =
        sess.maint.stats.evicted_tokens as f64 / (540.0 * groups_total as f64);
    assert!(
        (store_after as f64) < (store_before as f64) * (1.0 - 0.5 * retired_frac.min(1.0)),
        "store did not shrink: {store_before} -> {store_after} (retired {retired_frac:.2})"
    );
    // Live indexed keys are still retrievable under their absolute ids...
    let cache = &sess.caches[0][0];
    let live_ids = cache.indexed_ids();
    assert!(!live_ids.is_empty());
    let mut hits = 0;
    let probes: Vec<u32> = live_ids.iter().copied().step_by(37).take(6).collect();
    for &id in &probes {
        let out = sess.retrievers[0][0].retrieve(cache.key(id as usize), 32);
        if out.ids.contains(&id) {
            hits += 1;
        }
        for got in &out.ids {
            assert!(!cache.is_retired(*got as usize), "retrieved retired id {got}");
        }
    }
    assert!(hits >= probes.len() - 1, "live keys lost by the remap: {hits}/{}", probes.len());
    // ...and reclaimed ids resolve to nothing in the compacted map.
    let retired = cache.retired_ids();
    assert!(retired.len() >= 135);
    let reclaimed_probe: Vec<u32> = retired.iter().copied().take(64).collect();
    assert!(sess.groups[0][0].dense_ids_for(&reclaimed_probe).is_empty());
    // The session keeps decoding after the epoch.
    let out = eng.decode_step(&mut sess, 3).unwrap();
    assert!((out.token as usize) < eng.spec().vocab);
}

#[test]
fn truncate_and_fork_across_reclaim_generation() {
    // Truncate/fork correctness across a store-generation bump: both
    // paths resolve absolute ids against the *current* generation's map,
    // so they must keep working after dense ids were renumbered.
    let mut cfg = ServeConfig::default();
    cfg.model = "induction-mini".into();
    cfg.method = Method::RetrievalAttention;
    cfg.pattern = retrieval_attention::kvcache::StaticPattern { sink: 32, window: 128 };
    cfg.retrieval.top_k = 32;
    cfg.retrieval.ef = 64;
    cfg.retrieval.maintenance.drain_watermark = 16;
    cfg.retrieval.eviction.max_indexed = 128;
    cfg.retrieval.eviction.reclaim_ratio = 0.25;
    let eng = Engine::from_config(cfg).expect("engine init");
    let mut rng = Rng::seed_from(55);
    let s = tasks::passkey(&mut rng, 600, 0.5);
    let mut sess = eng.prefill(&s.prompt).unwrap();
    let _ = eng.generate(&mut sess, 30).unwrap();
    sess.flush_maintenance();
    assert!(sess.maint.stats.reclaims > 0, "setup: no generation bump happened");
    let gen = sess.groups[0][0].store_generation();
    assert!(gen > 0);

    // Fork after the bump: the copy-on-write fork shares the base's
    // frozen state — including its store generation, so its fronts pair
    // with its maps exactly as the base's did — and decodes independently.
    let mut fork = eng.fork_session(&mut sess).unwrap();
    assert_eq!(fork.groups[0][0].store_generation(), gen);
    assert_eq!(fork.len, sess.len);
    let out = eng.decode_step(&mut fork, 5).unwrap();
    assert!((out.token as usize) < eng.spec().vocab);
    fork.shutdown_maintenance();

    // Truncate the original across the bump: dropped ids resolve against
    // the current map; nothing at or past the cut stays retrievable.
    let probe_key: Vec<f32> = sess.caches[0][0].key(560).to_vec();
    eng.truncate_session(&mut sess, 400).unwrap();
    assert_eq!(sess.len, 400);
    for caches in &sess.caches {
        for c in caches {
            assert_eq!(c.len(), 400);
            assert!(c.indexed_end() <= 400);
        }
    }
    let out = sess.retrievers[0][0].retrieve(&probe_key, 64);
    assert!(
        out.ids.iter().all(|&id| (id as usize) < 400),
        "dropped id retrievable after post-reclaim truncate: {:?}",
        out.ids
    );
    // The truncated session keeps decoding (and may reclaim again).
    let out = eng.decode_step(&mut sess, 7).unwrap();
    assert!((out.token as usize) < eng.spec().vocab);
    sess.shutdown_maintenance();
}

#[test]
fn gqa_group_shares_one_id_map_memory_accounting() {
    // Regression (ROADMAP PR-1 follow-up): the dense→absolute id map is
    // shared per GQA group — llama3-mini has 8 query heads over 2 kv
    // heads, so the map must be charged per kv head (Appendix C), not
    // once per query head.
    let mut cfg = ServeConfig::default();
    cfg.model = "llama3-mini".into();
    cfg.method = Method::Flat;
    let eng = Engine::from_config(cfg).expect("engine init");
    let spec = eng.spec().clone();
    assert!(spec.q_heads > spec.kv_heads, "GQA geometry required for this regression");

    let heads: Vec<Vec<retrieval_attention::workload::geometry::HeadGeometry>> = (0..spec.layers)
        .map(|l| {
            (0..spec.kv_heads)
                .map(|k| {
                    retrieval_attention::workload::geometry::generate(
                        &retrieval_attention::workload::geometry::GeometryParams {
                            head_dim: spec.head_dim,
                            ..Default::default()
                        },
                        1024,
                        128,
                        (l * 13 + k) as u64,
                    )
                })
                .collect()
        })
        .collect();
    let sess = eng.synthetic_session(heads, Method::Flat).expect("session");

    // One group state per (layer, kv_head) — not per query head.
    let group_count: usize = sess.groups.iter().map(|l| l.len()).sum();
    assert_eq!(group_count, spec.layers * spec.kv_heads);
    // Every group's map covers its cache's indexed tier exactly once.
    let map_bytes: usize = sess.groups.iter().flatten().map(|g| g.map_bytes()).sum();
    let expected_map_bytes: usize = sess
        .caches
        .iter()
        .flatten()
        .map(|c| c.indexed_len() * std::mem::size_of::<u32>())
        .sum();
    assert_eq!(map_bytes, expected_map_bytes, "map must be charged once per kv head");
    // The shared key-store payload (the dominant host-RAM term) is also
    // charged once per kv head: groups × rows × dim × 4 bytes exactly.
    let store_bytes: usize = sess.groups.iter().flatten().map(|g| g.store_bytes()).sum();
    let payload: usize = sess
        .caches
        .iter()
        .flatten()
        .map(|c| c.indexed_len() * spec.head_dim * 4)
        .sum();
    assert!(store_bytes >= payload && store_bytes < payload + 4096, "store accounting drifted");
    // The total accounting is heads' index structures + per-GROUP shared
    // state; with the old per-query-head maps this would have been
    // group_size x larger on the map and store terms.
    let head_bytes: usize = sess.retrievers.iter().flatten().map(|r| r.memory_bytes()).sum();
    assert_eq!(sess.index_memory_bytes(), head_bytes + map_bytes + store_bytes);
}
